"""Worker script: distributed wsFFT correctness on 16 fake host devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_distributed_fft_worker.py
Exits 0 on success; prints PASS lines per case.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import distributed as dist  # noqa: E402
from repro.core import plan as planlib  # noqa: E402
from repro.core import twiddle as tw  # noqa: E402


def check(name, got, want, tol):
    err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-30)
    assert err < tol, f"{name}: rel err {err:.2e} > {tol}"
    print(f"PASS {name} rel_err={err:.2e}")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    rng = np.random.default_rng(42)

    # ---- 3D FFT, n^3 on 4x4 mesh (multi-pencil m = n/4) ----
    for n, method in [(8, "stockham"), (16, "four_step"), (16, "auto"),
                      (32, "auto")]:
        x = rng.standard_normal((n, n, n)) + 1j * rng.standard_normal((n, n, n))
        want = np.fft.fftn(x)
        plan = planlib.make_fft3d_plan(n, mesh, method=method)
        re, im = tw.to_planar(x)
        re = jax.device_put(re, plan.sharding())
        im = jax.device_put(im, plan.sharding())
        fwd, in_lay, out_lay = dist.make_fft(plan)
        yr, yi = jax.jit(fwd)(re, im)
        got = tw.from_planar((yr, yi))
        check(f"fft3d n={n} {method} out_layout={out_lay}", got, want, 3e-4)

        # inverse round trip (consumes forward layout, restores input layout)
        inv, _, _ = dist.make_fft(plan, inverse=True)
        br, bi = jax.jit(inv)(yr, yi)
        back = tw.from_planar((br, bi))
        check(f"ifft3d-roundtrip n={n} {method}", back, x, 3e-4)

    # ---- forward with restore_layout ----
    n = 16
    x = rng.standard_normal((n, n, n)) + 1j * rng.standard_normal((n, n, n))
    plan = planlib.make_fft3d_plan(n, mesh)
    re, im = (jax.device_put(a, plan.sharding()) for a in tw.to_planar(x))
    fwd, _, out_lay = dist.make_fft(plan, restore_layout=True)
    assert out_lay == plan.layout
    yr, yi = jax.jit(fwd)(re, im)
    check("fft3d restore_layout", tw.from_planar((yr, yi)), np.fft.fftn(x), 3e-4)

    # ---- overlap_chunks pipelined variant ----
    fwd, _, _ = dist.make_fft(plan, overlap_chunks=2)
    yr, yi = jax.jit(fwd)(re, im)
    check("fft3d overlap_chunks=2", tw.from_planar((yr, yi)), np.fft.fftn(x), 3e-4)

    # ---- batched 3D FFT (leading batch axis kept local per device) ----
    xb = rng.standard_normal((2, n, n, n)) + 1j * rng.standard_normal((2, n, n, n))
    fwdb, _, _ = dist.make_fft(plan, batch=True)
    reb, imb = tw.to_planar(xb)
    shb = jax.sharding.NamedSharding(mesh, P(None, "x", "y", None))
    reb, imb = jax.device_put(reb, shb), jax.device_put(imb, shb)
    yr, yi = jax.jit(fwdb)(reb, imb)
    wantb = np.fft.fftn(xb, axes=(1, 2, 3))
    check("fft3d batched", tw.from_planar((yr, yi)), wantb, 3e-4)

    # ---- 2D FFT on the flattened 16-device mesh ----
    for (n0, n1) in [(32, 64), (64, 64)]:
        x2 = rng.standard_normal((n0, n1)) + 1j * rng.standard_normal((n0, n1))
        plan2 = planlib.make_fft2d_plan(n0, n1, mesh)
        re, im = (jax.device_put(a, plan2.sharding()) for a in tw.to_planar(x2))
        fwd2, _, out_lay2 = dist.make_fft(plan2)
        yr, yi = jax.jit(fwd2)(re, im)
        check(f"fft2d {n0}x{n1} out_layout={out_lay2}",
              tw.from_planar((yr, yi)), np.fft.fft2(x2), 3e-4)
        inv2, _, _ = dist.make_fft(plan2, inverse=True)
        br, bi = jax.jit(inv2)(yr, yi)
        check(f"ifft2d-roundtrip {n0}x{n1}", tw.from_planar((br, bi)), x2, 3e-4)

    # ---- large 1D FFT via distributed four-step ----
    mesh_axes = ("x", "y")
    for (n1, n2) in [(64, 32), (64, 64)]:
        n = n1 * n2
        x1 = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        want = np.fft.fft(x1)
        a = x1.reshape(n1, n2)
        re, im = tw.to_planar(a)
        sh = jax.sharding.NamedSharding(mesh, P(mesh_axes, None))
        re, im = jax.device_put(re, sh), jax.device_put(im, sh)
        f = dist.make_fft1d_large(n1, n2, mesh, mesh_axes)
        dr, di = jax.jit(f)(re, im)
        d = tw.from_planar((dr, di))
        # y[j1 + n1*j2] = D[j1, j2]  ->  natural y = D.flatten(order='F')
        got = d.flatten(order="F")
        check(f"fft1d_large n={n} ({n1}x{n2})", got, want, 3e-4)
        fnat = dist.make_fft1d_large(n1, n2, mesh, mesh_axes, natural_order=True)
        dr, di = jax.jit(fnat)(re, im)
        got = tw.from_planar((dr, di)).flatten()
        check(f"fft1d_large natural n={n}", got, want, 3e-4)

    # ---- bf16 compute-dtype path (loose tol) ----
    n = 16
    x = rng.standard_normal((n, n, n)) + 1j * rng.standard_normal((n, n, n))
    plan = planlib.make_fft3d_plan(n, mesh, method="four_step",
                                   compute_dtype=jnp.bfloat16)
    re, im = (jax.device_put(a, plan.sharding()) for a in tw.to_planar(x))
    fwd, _, _ = dist.make_fft(plan)
    yr, yi = jax.jit(fwd)(re, im)
    check("fft3d bf16-compute", tw.from_planar((yr, yi)), np.fft.fftn(x), 5e-2)

    # ---- Pallas kernels inside shard_map (interpret mode) ----
    n = 16
    x = rng.standard_normal((n, n, n)) + 1j * rng.standard_normal((n, n, n))
    plan = planlib.make_fft3d_plan(n, mesh, method="stockham",
                                   kernel="pallas")
    re, im = (jax.device_put(a, plan.sharding()) for a in tw.to_planar(x))
    fwd, _, _ = dist.make_fft(plan)
    yr, yi = jax.jit(fwd)(re, im)
    check("fft3d pallas-kernel", tw.from_planar((yr, yi)), np.fft.fftn(x), 3e-4)

    print("ALL DISTRIBUTED FFT TESTS PASSED")


if __name__ == "__main__":
    main()
