"""Worker script: repro.fft facade correctness on 16 fake host devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_fft_facade_worker.py
Exits 0 on success; prints PASS lines per case.

Covers the ISSUE acceptance matrix: ranks 1/2/3 through the one
``fft.plan`` signature, complex-array AND planar front-ends, at least
the 'four_step' and 'block' methods, exact inverse(forward(x)) round
trips, and the jit-executable cache.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro.core import twiddle as tw  # noqa: E402


def check(name, got, want, tol):
    err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-30)
    assert err < tol, f"{name}: rel err {err:.2e} > {tol}"
    print(f"PASS {name} rel_err={err:.2e}")


def npfft(x, rank):
    axes = tuple(range(-rank, 0))
    return np.fft.fftn(x, axes=axes)


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    rng = np.random.default_rng(7)
    shapes = {1: (1024,), 2: (32, 64), 3: (16, 16, 16)}

    for rank, shape in shapes.items():
        x = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
        want = npfft(x, rank)
        for method in ("four_step", "block"):
            # donate=False: this matrix re-feeds the same operands
            # (donation itself is covered below)
            p = fft.plan(shape, mesh, method=method, donate=False)

            # complex front-end
            xc = jax.device_put(jnp.asarray(x, jnp.complex64), p.in_sharding)
            y = p.forward(xc)
            assert y.dtype == jnp.complex64, y.dtype
            check(f"rank{rank} {method} complex fwd", np.asarray(y, np.complex128), want, 3e-4)
            back = p.inverse(y)
            check(f"rank{rank} {method} complex roundtrip",
                  np.asarray(back, np.complex128), x, 3e-4)

            # planar front-end returns the form it was given
            re, im = tw.to_planar(x)
            fr, fi = p.forward((re, im))
            check(f"rank{rank} {method} planar fwd",
                  tw.from_planar((fr, fi)), want, 3e-4)
            br, bi = p.inverse((fr, fi))
            check(f"rank{rank} {method} planar roundtrip",
                  tw.from_planar((br, bi)), x, 3e-4)

            # the jitted-executable cache is keyed (direction, batch, dtype, form)
            n_keys = len(p._exec_cache)
            p.forward(xc)
            p.inverse((fr, fi))
            assert len(p._exec_cache) == n_keys == 4, p._exec_cache.keys()
        print(f"PASS rank{rank} exec cache stable across repeat calls")

    # donation on the real mesh: the default consumes the operand even
    # across the sharding rotation; donate=False keeps it reusable
    pdon = fft.plan((16, 16, 16), mesh)
    xd = jax.device_put(jnp.asarray(
        rng.standard_normal((16, 16, 16)), jnp.complex64), pdon.in_sharding)
    yd = pdon.forward(xd)
    assert xd.is_deleted(), "donated input must be consumed"
    try:
        _ = xd + 1
        raise AssertionError("reuse after donate must raise")
    except RuntimeError:
        pass
    assert not yd.is_deleted()
    print("PASS donation consumes input; donate=False covered above")

    # leading batch dims (replicated) ride along for every rank
    for rank, shape in shapes.items():
        xb = rng.standard_normal((2,) + shape) + 1j * rng.standard_normal((2,) + shape)
        p = fft.plan(shape, mesh)
        yb = p.forward(jnp.asarray(xb, jnp.complex64))
        check(f"rank{rank} batched fwd", np.asarray(yb, np.complex128),
              npfft(xb, rank), 3e-4)
        bb = p.inverse(yb)
        check(f"rank{rank} batched roundtrip", np.asarray(bb, np.complex128), xb, 3e-4)

    # sharding metadata: forward output lands where inverse consumes it
    p = fft.plan((16, 16, 16), mesh)
    y = p.forward(jax.device_put(
        jnp.asarray(rng.standard_normal((16, 16, 16)), jnp.complex64),
        p.in_sharding))
    assert y.sharding.is_equivalent_to(p.out_sharding, 3), (
        y.sharding, p.out_sharding)
    print("PASS rank3 out_sharding matches produced array")

    # restore_layout keeps both directions on the input sharding
    pr = fft.plan((16, 16, 16), mesh, restore_layout=True)
    assert pr.in_sharding == pr.out_sharding
    x = rng.standard_normal((16, 16, 16)) + 1j * rng.standard_normal((16, 16, 16))
    back = pr.inverse(pr.forward(jnp.asarray(x, jnp.complex64)))
    check("rank3 restore_layout roundtrip", np.asarray(back, np.complex128), x, 3e-4)

    print("ALL FFT FACADE TESTS PASSED")


if __name__ == "__main__":
    main()
