"""Worker script: kernel-tier equivalence on 16 fake host devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_kernel_tier_worker.py
Exits 0 on success; prints PASS lines per case.

The contract under test: with everything jitted (plans always are),
``kernel='pallas'`` (interpret mode on this CPU host) and
``kernel='reference'`` produce BIT-IDENTICAL outputs for the Stockham
method across every comm strategy — the interpret-mode kernel runs the
same float ops in the same order as the jnp reference, and XLA's jit
rounding is deterministic. Likewise the fused twiddle+transpose
supersteps (the default) are a pure positional rearrangement around
identical float ops, so ``fused=False`` re-plans match bit for bit.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro.fft import pencil as fpencil  # noqa: E402


STRATEGIES = ("all_to_all", "ppermute", "hierarchical",
              "pod_tree:x.2*x.2*y.4")


def check_bitwise(name, a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, f"{name}: shape {a.shape} != {b.shape}"
    assert np.array_equal(a, b), (
        f"{name}: max abs diff {np.max(np.abs(a - b)):.3e} (not bitwise)")
    print(f"PASS {name} bitwise")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    rng = np.random.default_rng(11)
    n = 16
    x = (rng.standard_normal((n, n, n))
         + 1j * rng.standard_normal((n, n, n))).astype(np.complex64)

    # ---- rank 3: pallas == reference, every strategy ----
    for comm in STRATEGIES:
        plans = {
            tier: fft.plan((n, n, n), mesh, method="stockham", comm=comm,
                           kernel=tier, donate=False)
            for tier in ("reference", "pallas")
        }
        ys = {t: p.forward(jnp.asarray(x)) for t, p in plans.items()}
        check_bitwise(f"fft3d {comm} pallas==reference",
                      ys["pallas"], ys["reference"])
        backs = {t: np.asarray(p.inverse(ys[t])) for t, p in plans.items()}
        check_bitwise(f"ifft3d {comm} pallas==reference",
                      backs["pallas"], backs["reference"])
        err = np.max(np.abs(backs["pallas"] - x))
        assert err < 1e-5, f"roundtrip err {err:.2e}"

    # ---- kernel='auto' resolves to 'reference' on CPU: bit-identical ----
    pa = fft.plan((n, n, n), mesh, method="stockham", donate=False)
    pr = fft.plan((n, n, n), mesh, method="stockham", kernel="reference",
                  donate=False)
    assert pa.resolved_kernel == "reference"
    check_bitwise("fft3d auto==reference (cpu)",
                  pa.forward(jnp.asarray(x)), pr.forward(jnp.asarray(x)))

    # ---- fused supersteps (default) == unfused re-plan, both tiers ----
    for tier in ("reference", "pallas"):
        plan3 = fft.plan((n, n, n), mesh, method="stockham", kernel=tier,
                         donate=False)
        fn_fused, _, _ = fpencil.make_fft(plan3._pplan, fused=True)
        fn_unfused, _, _ = fpencil.make_fft(plan3._pplan, fused=False)
        re = jax.device_put(jnp.asarray(x.real), plan3._pplan.sharding())
        im = jax.device_put(jnp.asarray(x.imag), plan3._pplan.sharding())
        yf = jax.jit(fn_fused)(re, im)
        yu = jax.jit(fn_unfused)(re, im)
        check_bitwise(f"fft3d fused==unfused ({tier})", yf[0], yu[0])
        check_bitwise(f"fft3d fused==unfused imag ({tier})", yf[1], yu[1])
        got = np.asarray(yf[0]) + 1j * np.asarray(yf[1])
        err = (np.max(np.abs(got - np.fft.fftn(x)))
               / np.max(np.abs(np.fft.fftn(x))))
        assert err < 3e-6, f"fused {tier} vs numpy rel err {err:.2e}"
        print(f"PASS fft3d fused-vs-numpy ({tier}) rel_err={err:.2e}")

    # ---- rank 1 (large1d four-step, fused columns-DFT) ----
    n1d = 4096
    x1 = (rng.standard_normal(n1d)
          + 1j * rng.standard_normal(n1d)).astype(np.complex64)
    for comm in ("all_to_all", "ppermute"):
        y1 = {
            tier: fft.plan((n1d,), mesh, method="stockham", comm=comm,
                           kernel=tier, donate=False).forward(jnp.asarray(x1))
            for tier in ("reference", "pallas")
        }
        check_bitwise(f"fft1d {comm} pallas==reference",
                      y1["pallas"], y1["reference"])
    err = (np.max(np.abs(np.asarray(y1["pallas"]) - np.fft.fft(x1)))
           / np.max(np.abs(np.fft.fft(x1))))
    assert err < 3e-6, f"fft1d rel err {err:.2e}"
    print(f"PASS fft1d-vs-numpy rel_err={err:.2e}")

    # ---- rank 2 ----
    x2 = (rng.standard_normal((64, 32))
          + 1j * rng.standard_normal((64, 32))).astype(np.complex64)
    y2 = {
        tier: fft.plan((64, 32), mesh, method="stockham", kernel=tier,
                       donate=False).forward(jnp.asarray(x2))
        for tier in ("reference", "pallas")
    }
    check_bitwise("fft2d pallas==reference", y2["pallas"], y2["reference"])

    # ---- real (rfft) plan: tier applies to the post-r2c supersteps ----
    xr = rng.standard_normal((n, n, n)).astype(np.float32)
    yr = {
        tier: fft.rplan((n, n, n), mesh, method="stockham",
                        kernel=tier).forward(jnp.asarray(xr))
        for tier in ("reference", "pallas")
    }
    check_bitwise("rfft3d pallas==reference", yr["pallas"], yr["reference"])
    err = np.max(np.abs(np.asarray(yr["pallas"]) - np.fft.rfftn(xr)))
    assert err < 1e-3, f"rfft err {err:.2e}"

    print("KERNEL_TIER_WORKER_OK")


if __name__ == "__main__":
    main()
