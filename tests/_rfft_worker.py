"""Worker script: real-input (rfft/irfft) plans on 16 fake devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_rfft_worker.py
Exits 0 on success; prints PASS lines per case.

Covers the acceptance matrix: ranks 1/2/3 vs ``np.fft.rfftn`` /
``np.fft.irfftn`` across every comm strategy and the registered
methods, exact round trips, leading batch dims, output shardings
(truncated axis gathered by default; distributed under
``padded_spectrum``), and overlap-pipeline bit-equivalence.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro import comm  # noqa: E402

RNG = np.random.default_rng(17)
SHAPES = {1: (1024,), 2: (32, 64), 3: (16, 16, 16)}


def nprfft(x, rank):
    return np.fft.rfftn(x, axes=tuple(range(-rank, 0)))


def check(name, got, want, tol=3e-4):
    err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-30)
    assert err < tol, f"{name}: rel err {err:.2e} > {tol}"
    print(f"PASS {name} rel_err={err:.2e}")


def check_strategy_matrix(mesh):
    for rank, shape in SHAPES.items():
        x = RNG.standard_normal(shape).astype(np.float32)
        want = nprfft(x, rank)
        ref = None
        for strategy in comm.names():
            p = fft.rplan(shape, mesh, comm=strategy)
            assert p.real and p.spectrum_shape[-1] == shape[-1] // 2 + 1
            xs = jax.device_put(jnp.asarray(x), p.in_sharding)
            y = p.forward(xs)
            assert y.shape == p.spectrum_shape, (y.shape, p.spectrum_shape)
            got = np.asarray(y, np.complex128)
            check(f"rank{rank} comm={strategy} rfft", got, want)
            if ref is None:
                ref = got
            assert np.array_equal(ref, got), (rank, strategy,
                                              "strategies disagree")
            back = p.inverse(y)
            assert not np.iscomplexobj(np.asarray(back))
            check(f"rank{rank} comm={strategy} roundtrip",
                  np.asarray(back, np.float64), x, 1e-4)
            # matches numpy's irfftn on the same (Hermitian) spectrum
            nb = np.fft.irfftn(want, s=shape, axes=tuple(range(-rank, 0)))
            assert np.max(np.abs(np.asarray(back, np.float64) - nb)) < 1e-4


def check_method_matrix(mesh):
    shape = (16, 16, 16)
    x = RNG.standard_normal(shape).astype(np.float32)
    want = nprfft(x, 3)
    for method in fft.available_methods():
        p = fft.rplan(shape, mesh, method=method)
        xs = jax.device_put(jnp.asarray(x), p.in_sharding)
        y = p.forward(xs)
        check(f"method={method} rfft", np.asarray(y, np.complex128), want)
        back = p.inverse(y)
        check(f"method={method} roundtrip", np.asarray(back, np.float64),
              x, 1e-4)


def check_shardings(mesh):
    for rank, shape in SHAPES.items():
        x = RNG.standard_normal(shape).astype(np.float32)
        p = fft.rplan(shape, mesh)
        y = p.forward(jax.device_put(jnp.asarray(x), p.in_sharding))
        assert y.sharding.is_equivalent_to(p.out_sharding, rank), (
            rank, y.sharding, p.out_sharding)
        back = p.inverse(y)
        assert back.sharding.is_equivalent_to(p.in_sharding, rank)
        print(f"PASS rank{rank} shardings: out={y.sharding.spec} "
              f"in={back.sharding.spec}")
    # default contract gathers the truncated axis into memory
    p3 = fft.rplan((16, 16, 16), mesh)
    assert p3.out_layout[-1] is None


def check_padded_mode(mesh):
    for rank, shape in ((2, (32, 64)), (3, (16, 16, 16))):
        nh = shape[-1] // 2 + 1
        x = RNG.standard_normal(shape).astype(np.float32)
        want = nprfft(x, rank)
        p = fft.rplan(shape, mesh, padded_spectrum=True)
        # the padded extent must shard evenly over the owning mesh group
        owner = p.out_layout[-1]
        psize = 1
        for a in (owner if isinstance(owner, tuple) else (owner,)):
            psize *= mesh.shape[a]
        assert p.spectrum_shape[-1] % psize == 0, (p.spectrum_shape, owner)
        assert p.spectrum_shape[-1] >= nh
        y = p.forward(jax.device_put(jnp.asarray(x), p.in_sharding))
        assert y.shape == p.spectrum_shape
        # the distributed native spectrum keeps the rotated layout
        assert y.sharding.is_equivalent_to(p.out_sharding, rank)
        check(f"rank{rank} padded rfft",
              np.asarray(y, np.complex128)[..., :nh], want)
        back = p.inverse(y)
        check(f"rank{rank} padded roundtrip", np.asarray(back, np.float64),
              x, 1e-4)
        # pad bins are dead: poisoning them must not change the inverse
        yj = np.asarray(y).copy()
        yj[..., nh:] = 1e6
        backj = p.inverse(jnp.asarray(yj))
        assert np.array_equal(np.asarray(backj), np.asarray(back)), rank
        print(f"PASS rank{rank} padded pad-bins inert")


def check_batch_and_cache(mesh):
    for rank, shape in SHAPES.items():
        xb = RNG.standard_normal((2,) + shape).astype(np.float32)
        p = fft.rplan(shape, mesh)
        yb = p.forward(jnp.asarray(xb))
        check(f"rank{rank} batched rfft", np.asarray(yb, np.complex128),
              nprfft(xb, rank))
        bb = p.inverse(yb)
        check(f"rank{rank} batched roundtrip", np.asarray(bb, np.float64),
              xb, 1e-4)
    p = fft.rplan((16, 16, 16), mesh)
    x = jnp.asarray(RNG.standard_normal((16, 16, 16)), jnp.float32)
    y = p.forward(x)
    n_keys = len(p._exec_cache)
    p.forward(x)
    p.inverse(y)
    p.inverse(y)
    assert len(p._exec_cache) == n_keys + 1, p._exec_cache.keys()
    print("PASS rfft exec cache stable across repeat calls")


def check_overlap_equivalence(mesh):
    """Every strategy x chunk depth is bit-identical — the (fft, swap)
    pairs AND the r2c split-combine pair (first forward superstep, last
    inverse superstep) now both pipeline."""
    shape = (16, 16, 16)
    x = RNG.standard_normal(shape).astype(np.float32)
    base, rbase = None, None
    for strategy in comm.names():
        for oc in (1, 2, 4):
            p = fft.rplan(shape, mesh, comm=strategy, overlap_chunks=oc)
            xs = jax.device_put(jnp.asarray(x), p.in_sharding)
            got = np.asarray(p.forward(xs))
            if base is None:
                base = got
            assert np.array_equal(base, got), (strategy, oc)
            back = np.asarray(p.inverse(jnp.asarray(got)))
            if rbase is None:
                rbase = back
            assert np.array_equal(rbase, back), (strategy, oc, "inverse")
    print("PASS rfft overlap pipeline (incl. r2c split-combine pair) "
          "bit-identical across strategies x chunks")


def check_overlap_fallback(mesh):
    """Chunk counts nothing divides fall back bit-exactly to the
    unpipelined path, per strategy (the r2c pair falls back by the same
    shared rule); rank-1 odd batches fall back in the real four-step."""
    shape = (16, 16, 16)
    x = RNG.standard_normal(shape).astype(np.float32)
    for strategy in comm.names():
        base = None
        for oc in (1, 3, 5):
            p = fft.rplan(shape, mesh, comm=strategy, overlap_chunks=oc)
            xs = jax.device_put(jnp.asarray(x), p.in_sharding)
            got = np.asarray(p.forward(xs))
            if base is None:
                base = got
            assert np.array_equal(base, got), (strategy, oc)
        print(f"PASS rfft overlap fallback comm={strategy} bit-exact")
    xb = RNG.standard_normal((3, 1024)).astype(np.float32)
    a = np.asarray(fft.rplan((1024,), mesh,
                             overlap_chunks=1).forward(jnp.asarray(xb)))
    b = np.asarray(fft.rplan((1024,), mesh,
                             overlap_chunks=2).forward(jnp.asarray(xb)))
    assert np.array_equal(a, b)
    print("PASS rfft overlap fallback rank-1 odd batch bit-exact")


def check_auto_and_cost(mesh):
    p = fft.rplan((16, 16, 16), mesh, comm='auto')
    assert p.comm in comm.names()
    rep = p.cost_report()
    assert 'rfft' in rep and 'swap' in rep
    x = RNG.standard_normal((16, 16, 16)).astype(np.float32)
    back = p.inverse(p.forward(jax.device_put(jnp.asarray(x),
                                              p.in_sharding)))
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-4
    print(f"PASS rfft comm='auto' plan: strategy={p.comm} "
          f"overlap={p.overlap_chunks} method={p.method}")


def check_restore_layout(mesh):
    shape = (16, 16, 16)
    x = RNG.standard_normal(shape).astype(np.float32)
    p = fft.rplan(shape, mesh, restore_layout=True)
    y = p.forward(jax.device_put(jnp.asarray(x), p.in_sharding))
    check("restore_layout rfft", np.asarray(y, np.complex128), nprfft(x, 3))
    back = p.inverse(y)
    check("restore_layout roundtrip", np.asarray(back, np.float64), x, 1e-4)


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    check_strategy_matrix(mesh)
    check_method_matrix(mesh)
    check_shardings(mesh)
    check_padded_mode(mesh)
    check_batch_and_cache(mesh)
    check_overlap_equivalence(mesh)
    check_overlap_fallback(mesh)
    check_auto_and_cost(mesh)
    check_restore_layout(mesh)
    print("RFFT_WORKER_OK")


if __name__ == "__main__":
    main()
