"""Worker script: continuous multi-shape serving on 16 fake devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_serve_drainer_worker.py
Exits 0 on success; prints PASS lines per case.

Covers the acceptance contract on a real multi-device mesh: ONE
background engine (no explicit flush anywhere) serves N producer
threads submitting a mixed stream of >= 3 distinct shapes, complex and
real, forward and inverse, and every output is BIT-IDENTICAL to
per-request plan execution; deadline-only and watermark-only loads
both dispatch; an injected drainer fault re-queues (never drops) and
either retries to success or surfaces on ``result()``.

Every per-request reference is computed BEFORE its engine phase runs:
two host threads executing multi-device collectives concurrently (a
reference ``plan.forward`` racing the drainer's dispatches) can
deadlock XLA's CPU collectives — the engine itself serializes all its
dispatches through the one drainer thread, which is exactly why the
serving path is safe.
"""
import os
import threading

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["REPRO_SERVE_SCHEDULES"] = ""       # deterministic picks

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro.serve import FFTEngine  # noqa: E402

RNG = np.random.default_rng(47)
SHAPES = [(8, 8, 8), (4, 4, 4), (16, 16)]


def ref_plans(mesh):
    plans = {}
    for shape in SHAPES:
        plans[(shape, False)] = fft.plan(shape, mesh, donate=False)
        plans[(shape, True)] = fft.rplan(shape, mesh)
    return plans


def ref_forward(plans, shape, x):
    p = plans[(shape, not np.iscomplexobj(x))]
    return np.asarray(
        p.forward(jax.device_put(jnp.asarray(x), p.in_sharding)))


def ref_inverse(plans, shape, real, spec):
    p = plans[(shape, real)]
    return np.asarray(p.inverse(
        jax.device_put(jnp.asarray(spec), p.out_sharding)))


def make_request(i, shape):
    x = RNG.standard_normal(shape).astype(np.float32)
    if i % 2 == 0:
        x = (x + 1j * RNG.standard_normal(shape)).astype(np.complex64)
    return x


def check_concurrent_producers(mesh, plans):
    """3 producer threads x 4 mixed requests plus an inverse each, one
    shared background engine, zero flush() calls: every output
    bit-identical to the precomputed per-request execution."""
    n_threads, per_thread = 3, 4
    work = []                                  # per thread: (reqs, inv)
    for tid in range(n_threads):
        reqs = []
        for i in range(per_thread):
            shape = SHAPES[(tid + i) % len(SHAPES)]
            x = make_request(tid + i, shape)
            reqs.append((shape, x, ref_forward(plans, shape, x)))
        shape, x, spec = reqs[0]
        real = not np.iscomplexobj(x)
        inv = (shape, real, spec, ref_inverse(plans, shape, real, spec))
        work.append((reqs, inv))
    errors = []

    with FFTEngine(mesh=mesh, max_wait_ms=100.0, max_coalesce=4) as eng:

        def producer(tid):
            try:
                reqs, inv = work[tid]
                tickets = [eng.submit(x) for _, x, _ in reqs]
                for (shape, x, want), t in zip(reqs, tickets):
                    got = np.asarray(t.result(timeout=600))
                    assert np.array_equal(got, want), (tid, shape)
                shape, real, spec, want_back = inv
                back = eng.submit(spec, direction='inv',
                                  real=real).result(timeout=600)
                assert np.array_equal(np.asarray(back), want_back), \
                    (tid, 'inv', shape)
            except Exception as e:              # surface on the main thread
                errors.append((tid, repr(e)))

        threads = [threading.Thread(target=producer, args=(tid,))
                   for tid in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errors, errors
    print(f"PASS {n_threads} producer threads x {per_thread} mixed "
          f"requests ({len(SHAPES)} shapes, complex+real, fwd+inv) "
          f"bit-identical, no flush()")


def check_deadline_only(mesh, plans):
    """A watermark that never trips: the 50 ms deadline alone must
    dispatch everything."""
    reqs = []
    for i in range(5):
        shape = SHAPES[i % 2]
        x = make_request(i, shape)
        reqs.append((shape, x, ref_forward(plans, shape, x)))
    with FFTEngine(mesh=mesh, max_wait_ms=50.0, watermark=10**6,
                   max_coalesce=4) as eng:
        tickets = [eng.submit(x) for _, x, _ in reqs]
        for (shape, x, want), t in zip(reqs, tickets):
            assert np.array_equal(np.asarray(t.result(timeout=600)),
                                  want), shape
    print("PASS deadline-only load (watermark never trips) bit-identical")


def check_watermark_only(mesh, plans):
    """No deadline at all: dispatch happens purely on the width
    watermark."""
    shape = SHAPES[0]
    reqs = [make_request(2 * i, shape) for i in range(4)]  # all complex
    wants = [ref_forward(plans, shape, x) for x in reqs]
    with FFTEngine(mesh=mesh, watermark=2, max_coalesce=2) as eng:
        tickets = [eng.submit(x) for x in reqs]
        for want, t in zip(wants, tickets):
            assert np.array_equal(np.asarray(t.result(timeout=600)), want)
    print("PASS watermark-only load (no deadline) bit-identical")


def check_exception_injection(mesh, plans):
    """A drainer fault re-queues the group (never drops it): with
    retries left the retry succeeds bit-identically; with retries
    exhausted the fault surfaces on result()."""
    shape = SHAPES[1]
    x = make_request(0, shape)
    want = ref_forward(plans, shape, x)

    eng = FFTEngine(mesh=mesh, max_wait_ms=20.0, retries=3, max_coalesce=4)
    real_run = eng._run_group
    fails = {'left': 2}

    def flaky(*a, **k):
        if fails['left'] > 0:
            fails['left'] -= 1
            raise RuntimeError("injected drainer fault")
        return real_run(*a, **k)

    eng._run_group = flaky
    with eng:
        got = np.asarray(eng.submit(x).result(timeout=600))
    assert fails['left'] == 0                  # the fault really fired
    assert np.array_equal(got, want)

    eng2 = FFTEngine(mesh=mesh, max_wait_ms=20.0, retries=1, max_coalesce=4)

    def boom(*a, **k):
        raise RuntimeError("persistent drainer fault")

    eng2._run_group = boom
    with eng2:
        t = eng2.submit(x)
        try:
            t.result(timeout=600)
            raise AssertionError("persistent fault must surface on result()")
        except RuntimeError as e:
            assert "persistent drainer fault" in str(e)
    print("PASS drainer exception injection: re-queued + retried to "
          "success; persistent fault surfaces on result()")


def check_donated_inflight_snapshot(mesh, plans):
    """A background engine serving donated jax-array requests: an
    injected post-dispatch fault consumes the donated operands, and the
    retry still succeeds from the in-flight snapshots."""
    shape = SHAPES[1]
    host = make_request(0, shape)
    want = ref_forward(plans, shape, host)
    eng = FFTEngine(mesh=mesh, max_wait_ms=20.0, retries=2, max_coalesce=4)
    real_run = eng._run_group
    state = {'armed': True}

    def run_then_fail(*a, **k):
        out = real_run(*a, **k)
        if state['armed']:
            state['armed'] = False
            raise RuntimeError("post-dispatch fault")
        return out

    eng._run_group = run_then_fail
    p = plans[(shape, False)]
    xj = jax.device_put(jnp.asarray(host), p.in_sharding)
    with eng:
        got = np.asarray(eng.submit(xj).result(timeout=600))
    assert not state['armed']                  # the fault fired
    assert xj.is_deleted()                     # donation still happened
    assert np.array_equal(got, want)
    print("PASS donated in-flight snapshot: post-dispatch fault retried "
          "bit-identically")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    plans = ref_plans(mesh)
    check_concurrent_producers(mesh, plans)
    check_deadline_only(mesh, plans)
    check_watermark_only(mesh, plans)
    check_exception_injection(mesh, plans)
    check_donated_inflight_snapshot(mesh, plans)
    print("SERVE_DRAINER_WORKER_OK")


if __name__ == "__main__":
    main()
