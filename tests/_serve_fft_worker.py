"""Worker script: the batched FFT serving engine on 16 fake devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_serve_fft_worker.py
Exits 0 on success; prints PASS lines per case.

Covers the acceptance contract on a real multi-device mesh: engine
outputs BIT-IDENTICAL to per-request plan execution for complex and
real requests across every comm strategy, remainder groups, inverse
serving, donation of staged (not caller) buffers, and the overlap
fallback inside batched executions.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["REPRO_SERVE_SCHEDULES"] = ""       # deterministic picks

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro import comm  # noqa: E402
from repro.serve import FFTEngine  # noqa: E402

RNG = np.random.default_rng(41)
SHAPE = (16, 16, 16)


def per_request_refs(shape, mesh, reqs, strategy):
    pc = fft.plan(shape, mesh, comm=strategy, donate=False)
    pr = fft.rplan(shape, mesh, comm=strategy)
    refs = []
    for x in reqs:
        p = pc if np.iscomplexobj(x) else pr
        refs.append(np.asarray(
            p.forward(jax.device_put(jnp.asarray(x), p.in_sharding))))
    return refs


def check_engine_bit_identity(mesh):
    for strategy in comm.names():
        eng = FFTEngine(SHAPE, mesh, comm=strategy)
        reqs = []
        for i in range(7):                    # 7: exercises a remainder group
            x = RNG.standard_normal(SHAPE).astype(np.float32)
            if i % 2 == 0:
                x = (x + 1j * RNG.standard_normal(SHAPE)).astype(np.complex64)
            reqs.append(x)
        outs = eng.transform(reqs)
        refs = per_request_refs(SHAPE, mesh, reqs, strategy)
        for i, (o, r) in enumerate(zip(outs, refs)):
            assert np.array_equal(np.asarray(o), r), (strategy, i)
        w, c = eng.schedule(False)
        print(f"PASS engine comm={strategy} bit-identical "
              f"(7 mixed requests, w={w} c={c})")


def check_engine_inverse_roundtrip(mesh):
    eng = FFTEngine(SHAPE, mesh)
    xc = [(RNG.standard_normal(SHAPE)
           + 1j * RNG.standard_normal(SHAPE)).astype(np.complex64)
          for _ in range(3)]
    xr = [RNG.standard_normal(SHAPE).astype(np.float32) for _ in range(3)]
    specs = eng.transform(xc + xr)
    backs = eng.transform(specs, direction='inv')
    for x, b in zip(xc + xr, backs):
        assert np.max(np.abs(np.asarray(b) - x)) < 1e-4
    assert not np.iscomplexobj(np.asarray(backs[-1]))
    print("PASS engine inverse serving round trips (complex + real)")


def check_engine_donation(mesh):
    p = fft.plan(SHAPE, mesh, donate=False)

    def make():
        return jax.device_put(
            jnp.asarray((RNG.standard_normal(SHAPE)
                         + 1j * RNG.standard_normal(SHAPE)), jnp.complex64),
            p.in_sharding)

    # donate=True engine consumes submitted jax arrays (plan contract)
    eng = FFTEngine(SHAPE, mesh)
    xs = [make() for _ in range(4)]
    eng.transform(xs)
    assert all(x.is_deleted() for x in xs)
    # donate=False engine keeps them reusable
    engnd = FFTEngine(SHAPE, mesh, donate=False)
    xs2 = [make() for _ in range(4)]
    a = engnd.transform(xs2)
    b = engnd.transform(xs2)
    assert not any(x.is_deleted() for x in xs2)
    assert all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(a, b))
    # direct donating plan consumes its operand on this mesh too
    pd = fft.plan(SHAPE, mesh)
    x = make()
    y = pd.forward(x)
    assert x.is_deleted()
    try:
        _ = x + 1
        raise AssertionError("reuse after donate must raise")
    except RuntimeError:
        pass
    assert not y.is_deleted()
    print("PASS donation: donated requests consumed, donate=False "
          "reusable, reuse-after-donate raises")


def check_engine_overlap_fallback(mesh):
    # a 6-wide group with overlap_chunks=4: the batch axis (6) does not
    # divide, so pairs fall back (or chunk another free axis) per the
    # shared rule — results must stay bit-identical
    eng = FFTEngine(SHAPE, mesh, max_coalesce=8, overlap_chunks=4)
    eng.set_schedule(6, 4)
    plan = eng.plan_for(False)
    assert plan.overlap_chunks == 4
    reqs = [(RNG.standard_normal(SHAPE)
             + 1j * RNG.standard_normal(SHAPE)).astype(np.complex64)
            for _ in range(6)]
    outs = eng.transform(reqs)
    assert eng.schedule(False) == (6, 4)       # preset actually served
    refs = per_request_refs(SHAPE, mesh, reqs, plan.comm)
    for o, r in zip(outs, refs):
        assert np.array_equal(np.asarray(o), r)
    print("PASS engine overlap fallback (non-dividing width) bit-identical")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    check_engine_bit_identity(mesh)
    check_engine_inverse_roundtrip(mesh)
    check_engine_donation(mesh)
    check_engine_overlap_fallback(mesh)
    print("SERVE_FFT_WORKER_OK")


if __name__ == "__main__":
    main()
