"""Worker script: the multi-tenant FFT service on 16 fake devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_serve_service_worker.py
Exits 0 on success; prints PASS lines per case.

Covers the acceptance contract on a real multi-device mesh, over a
real unix socket:

* CASE 1 — three tenants stream mixed shapes/kinds (complex and real,
  forward and inverse) concurrently and every served output is
  BIT-IDENTICAL to direct per-request plan execution.
* CASE 2 — one tenant saturates its inflight quota: it observes typed
  RETRY_AFTER backpressure while a well-behaved tenant keeps serving
  with zero rejections and an un-degraded p99.
* CASE 3 — SLO classes order the wire: batch-class requests sit out a
  long coalescing wait until one interactive-class request's deadline
  ripens the shared queue and the whole group dispatches promptly.

Every per-request reference is computed BEFORE any service traffic:
two host threads executing multi-device collectives concurrently can
deadlock XLA's CPU collectives — the service serializes all dispatch
through the engine's one drainer thread, which is exactly why the
serving path is safe.
"""
import os
import tempfile
import threading
import time

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["REPRO_SERVE_SCHEDULES"] = ""       # deterministic picks

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro.serve import (FFTClient, FFTEngine, FFTService,  # noqa: E402
                         RetryAfter, SLOClass, TenantConfig)

RNG = np.random.default_rng(53)
SHAPES = [(8, 8, 8), (4, 4, 4), (16, 16)]
SOCK = os.path.join(tempfile.mkdtemp(prefix="serve_service_"), "s.sock")


def ref_plans(mesh):
    plans = {}
    for shape in SHAPES:
        plans[(shape, False)] = fft.plan(shape, mesh, donate=False)
        plans[(shape, True)] = fft.rplan(shape, mesh)
    return plans


def ref_forward(plans, shape, x):
    p = plans[(shape, not np.iscomplexobj(x))]
    return np.asarray(
        p.forward(jax.device_put(jnp.asarray(x), p.in_sharding)))


def ref_inverse(plans, shape, spec):
    p = plans[(shape, False)]
    return np.asarray(p.inverse(
        jax.device_put(jnp.asarray(spec), p.out_sharding)))


def make_stream(seed, count):
    """(kind, operand) pairs: rotating shapes, complex/real forward
    plus a complex inverse every 5th request."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(count):
        shape = SHAPES[i % len(SHAPES)]
        if i % 5 == 4:
            spec = (rng.standard_normal(shape)
                    + 1j * rng.standard_normal(shape)).astype(np.complex64)
            out.append(('inv', spec))
        elif i % 2:
            x = (rng.standard_normal(shape)
                 + 1j * rng.standard_normal(shape)).astype(np.complex64)
            out.append(('fwd', x))
        else:
            out.append(('fwd',
                        rng.standard_normal(shape).astype(np.float32)))
    return out


def case1_multi_tenant_bit_identity(eng, plans):
    streams = {name: make_stream(seed, 10)
               for name, seed in (('alice', 1), ('bob', 2), ('carol', 3))}
    refs = {}                                  # BEFORE any serving
    for name, stream in streams.items():
        for i, (d, x) in enumerate(stream):
            refs[(name, i)] = (ref_forward(plans, x.shape, x) if d == 'fwd'
                               else ref_inverse(plans, x.shape, x))

    svc = FFTService(
        engine=eng, persist_policy=False,
        tenants=[TenantConfig(n, max_inflight=16) for n in streams],
    ).start(SOCK)
    failures = []

    def run(name, stream):
        try:
            with FFTClient(SOCK, tenant=name) as c:
                tickets = []
                for d, x in stream:
                    real = None if d == 'fwd' else False
                    tickets.append(c.submit(x, direction=d, real=real))
                for i, t in enumerate(tickets):
                    got = np.asarray(t.result(timeout=600))
                    if not np.array_equal(got, refs[(name, i)]):
                        raise AssertionError(
                            f"{name}[{i}]: served output != direct plan "
                            f"execution (max abs diff "
                            f"{np.abs(got - refs[(name, i)]).max():g})")
                c.drain(timeout=120)
        except BaseException as exc:
            failures.append((name, repr(exc)))

    threads = [threading.Thread(target=run, args=(n, s))
               for n, s in streams.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=900)
        assert not t.is_alive(), "client thread wedged"
    assert not failures, failures

    with FFTClient(SOCK, tenant='alice') as probe:
        m = probe.metrics()
    for name in streams:
        tm = m['tenants'][name]
        assert tm['completed'] == 10 and tm['failed'] == 0, (name, tm)
        assert tm['rejected'] == {}, (name, tm)
    assert m['service']['dispatch']['groups'] > 0
    svc.close(drain=True)
    for name in streams:
        print(f"PASS case1 {name}: 10 mixed requests bit-identical, "
              f"0 rejections")


def case2_quota_isolation(eng, plans):
    shape = SHAPES[0]
    good_reqs = [(RNG.standard_normal(shape)
                  + 1j * RNG.standard_normal(shape)).astype(np.complex64)
                 for _ in range(8)]
    good_refs = [ref_forward(plans, shape, x) for x in good_reqs]
    flood_x = (RNG.standard_normal(shape)
               + 1j * RNG.standard_normal(shape)).astype(np.complex64)
    _ = ref_forward(plans, shape, flood_x)     # warm nothing extra

    svc = FFTService(
        engine=eng, persist_policy=False,
        tenants=[TenantConfig('good', max_inflight=8),
                 TenantConfig('flood', max_inflight=2)],
    ).start(SOCK)

    def serve_good(latencies):
        with FFTClient(SOCK, tenant='good') as c:
            for x, ref in zip(good_reqs, good_refs):
                t0 = time.monotonic()
                got = np.asarray(c.submit(x).result(timeout=600))
                latencies.append((time.monotonic() - t0) * 1e3)
                assert np.array_equal(got, ref)

    # baseline: the good tenant alone
    base = []
    serve_good(base)

    # under flood: 'flood' fire-hoses far past its quota of 2 while the
    # good tenant keeps its sequential stream going
    flood_stats = {'rejected': 0, 'served': 0}
    underf = []

    def run_flood():
        with FFTClient(SOCK, tenant='flood') as c:
            tickets = [c.submit(flood_x) for _ in range(60)]
            for t in tickets:
                try:
                    t.result(timeout=600)
                    flood_stats['served'] += 1
                except RetryAfter as ra:
                    assert ra.reason in ('tenant_quota', 'rate'), ra
                    assert ra.retry_after_ms > 0
                    flood_stats['rejected'] += 1

    tf = threading.Thread(target=run_flood)
    tg = threading.Thread(target=serve_good, args=(underf,))
    tf.start()
    tg.start()
    for t in (tf, tg):
        t.join(timeout=900)
        assert not t.is_alive(), "case2 thread wedged"

    assert flood_stats['rejected'] > 0, flood_stats
    assert flood_stats['served'] >= 2, flood_stats

    def p99(v):
        s = sorted(v)
        return s[min(len(s) - 1, int(0.99 * len(s)))]

    # isolation: the good tenant saw zero rejections and its p99 is
    # not degraded beyond noise (generous bound: 10x baseline + 500ms)
    with FFTClient(SOCK, tenant='good') as probe:
        m = probe.metrics()
    assert m['tenants']['good']['rejected'] == {}, m['tenants']['good']
    assert m['tenants']['flood']['rejected'], m['tenants']['flood']
    bound = 10.0 * p99(base) + 500.0
    assert p99(underf) <= bound, (p99(base), p99(underf), bound)
    svc.close(drain=True)
    print(f"PASS case2: flood rejected={flood_stats['rejected']} "
          f"served={flood_stats['served']}; good p99 "
          f"{p99(underf):.1f}ms <= {bound:.1f}ms (baseline "
          f"{p99(base):.1f}ms), 0 rejections")


def case3_slo_ordering(eng, plans):
    shape = SHAPES[0]
    xs = [(RNG.standard_normal(shape)
           + 1j * RNG.standard_normal(shape)).astype(np.complex64)
          for _ in range(4)]
    refs = [ref_forward(plans, shape, x) for x in xs]

    eng.set_drainer(watermark=16, max_wait_ms=None)
    svc = FFTService(
        engine=eng, persist_policy=False, policy=None,
        slo_classes={
            'batch': SLOClass('batch', deadline_ms=120000,
                              max_wait_ms=30000),
            'rush': SLOClass('rush', deadline_ms=200, max_wait_ms=1.0),
        },
        tenants=[TenantConfig('mix', max_inflight=8, slo='batch')],
    ).start(SOCK)
    with FFTClient(SOCK, tenant='mix') as c:
        t0 = time.monotonic()
        batch = [c.submit(x) for x in xs[:3]]  # 30s wait: they sit
        time.sleep(0.3)
        assert not any(t.done for t in batch), \
            "batch requests dispatched before any deadline/watermark"
        rush = c.submit(xs[3], slo='rush')     # 1ms deadline: ripens all
        outs = [np.asarray(t.result(timeout=600))
                for t in batch + [rush]]
        dt = time.monotonic() - t0
        for got, ref in zip(outs, refs):
            assert np.array_equal(got, ref)
        # far sooner than the 30s batch wait: the interactive deadline
        # ordered the whole shared queue
        assert dt < 20.0, f"queue ripened in {dt:.1f}s (batch wait 30s)"
        c.drain(timeout=120)
    svc.close(drain=True)
    print(f"PASS case3: 3 batch + 1 rush dispatched together in "
          f"{dt:.2f}s (<< 30s batch wait), bit-identical")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    plans = ref_plans(mesh)
    with FFTEngine(mesh=mesh, max_wait_ms=20.0,
                   schedule_table=None) as eng:
        case1_multi_tenant_bit_identity(eng, plans)
        case2_quota_isolation(eng, plans)
        case3_slo_ordering(eng, plans)
    print("SERVE_SERVICE_WORKER_OK")


if __name__ == "__main__":
    main()
