"""Chaos worker: the resilient multi-tenant service on 16 fake
devices under a seeded fault-injection plan.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_service_chaos_worker.py
Exits 0 on success; prints PASS lines per case.

The acceptance contract of the fault-injection PR, on a real mesh
over real unix sockets:

* CASE 1 — chaos soak: connection drops, truncated result frames,
  slow reads, accept delays, drainer stalls and clock skew all fire
  mid-stream while three tenants run mixed forward/inverse streams
  through ``FFTClient.transform``. NOTHING hangs, every operand is
  served exactly once, and every served output is BIT-IDENTICAL to
  direct plan execution.
* CASE 2 — fairness: a tenant flooding 3x the victim's load cannot
  push the equal-weight victim's completed share below 40% (weighted
  deficit round-robin), observed via the scheduler-share metrics.
* CASE 3 — idempotent resubmit: a scripted drop of the first RESULT
  frame forces a reconnect+resubmit; the cached result is
  RE-DELIVERED, never recomputed. A mid-flight drop re-attaches
  delivery to the new connection. Idle connections are reaped on the
  heartbeat timeout while keepalive clients survive.
* CASE 4 — brownout: consecutive injected dispatch failures trip the
  circuit breaker; batch traffic sheds with typed
  ``RETRY_AFTER('brownout')`` while interactive traffic still serves;
  after the cooldown a half-open probe closes it and the failed keys
  recompute successfully (failures are never cached).
* CASE 5 — hot reload: an admin RELOAD bumps the config generation,
  re-weights a live tenant and retires a missing one atomically —
  with the retired tenant's inflight request still served.

Every per-request reference is computed BEFORE any service traffic:
two host threads executing multi-device collectives concurrently can
deadlock XLA's CPU collectives — the service serializes all dispatch
through the engine's one drainer thread.
"""
import os
import tempfile
import threading
import time

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["REPRO_SERVE_SCHEDULES"] = ""       # deterministic picks

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402
from repro.serve import (BrownoutBreaker, FaultPlan, FaultPoint,  # noqa: E402
                         FFTClient, FFTEngine, FFTService, RetryAfter,
                         TenantConfig)

RNG = np.random.default_rng(101)
SHAPES = [(8, 8, 8), (4, 4, 4)]
TMP = tempfile.mkdtemp(prefix="serve_chaos_")


def sock_path(case):
    return os.path.join(TMP, f"c{case}.sock")


def ref_plans(mesh):
    plans = {}
    for shape in SHAPES:
        plans[(shape, False)] = fft.plan(shape, mesh, donate=False)
        plans[(shape, True)] = fft.rplan(shape, mesh)
    return plans


def ref_forward(plans, shape, x):
    p = plans[(shape, not np.iscomplexobj(x))]
    return np.asarray(
        p.forward(jax.device_put(jnp.asarray(x), p.in_sharding)))


def ref_inverse(plans, shape, spec):
    p = plans[(shape, False)]
    return np.asarray(p.inverse(
        jax.device_put(jnp.asarray(spec), p.out_sharding)))


def creq(shape):
    return (RNG.standard_normal(shape)
            + 1j * RNG.standard_normal(shape)).astype(np.complex64)


def make_stream(seed, count):
    """(kind, operand) pairs: rotating shapes, complex/real forward
    plus a complex inverse every 5th request."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(count):
        shape = SHAPES[i % len(SHAPES)]
        if i % 5 == 4:
            spec = (rng.standard_normal(shape)
                    + 1j * rng.standard_normal(shape)).astype(np.complex64)
            out.append(('inv', spec))
        elif i % 2:
            x = (rng.standard_normal(shape)
                 + 1j * rng.standard_normal(shape)).astype(np.complex64)
            out.append(('fwd', x))
        else:
            out.append(('fwd',
                        rng.standard_normal(shape).astype(np.float32)))
    return out


def connect(sock, tenant, attempts=6, **kw):
    """Client construction with retry: an armed reader/writer fault
    can kill the handshake itself; a resilient caller just redials."""
    last = None
    for i in range(attempts):
        try:
            return FFTClient(sock, tenant=tenant, **kw)
        except (ConnectionError, OSError) as exc:
            last = exc
            time.sleep(0.02 * (i + 1))
    raise AssertionError(f"could not connect as {tenant!r}: {last}")


# ---------------------------------------------------------------------------
# CASE 1 — chaos soak: faults everywhere, exactly-once, bit-identical
# ---------------------------------------------------------------------------

def case1_chaos_soak(eng, plans):
    streams = {name: make_stream(seed, 12)
               for name, seed in (('alice', 11), ('bob', 12), ('carol', 13))}
    refs = {}                                  # BEFORE any serving
    for name, stream in streams.items():
        for i, (d, x) in enumerate(stream):
            refs[(name, i)] = (ref_forward(plans, x.shape, x) if d == 'fwd'
                               else ref_inverse(plans, x.shape, x))

    plan = FaultPlan(seed=7, points=[
        FaultPoint('service.writer', 'drop', p=0.06, limit=5),
        FaultPoint('service.writer', 'truncate', p=0.04, limit=3),
        FaultPoint('service.reader', 'drop', p=0.02, limit=3),
        FaultPoint('service.reader', 'delay', p=0.05, delay_s=0.02,
                   limit=10),
        FaultPoint('service.accept', 'delay', p=0.3, delay_s=0.01,
                   limit=5),
        FaultPoint('engine.drainer', 'stall', every=25, delay_s=0.05,
                   limit=4),
        FaultPoint('policy.clock', 'skew', every=40, skew_s=5.0, limit=3),
    ])
    sock = sock_path(1)
    svc = FFTService(
        engine=eng, persist_policy=False, faults=plan,
        tenants=[TenantConfig(n, max_inflight=16) for n in streams],
    ).start(sock)
    failures = []

    def run(name, stream):
        try:
            c = connect(sock, name)
            with c:
                for i, (d, x) in enumerate(stream):
                    real = None if d == 'fwd' else False
                    [got] = c.transform([x], direction=d, real=real,
                                        timeout=90.0, deadline_s=90.0)
                    got = np.asarray(got)
                    if not np.array_equal(got, refs[(name, i)]):
                        raise AssertionError(
                            f"{name}[{i}]: served output != direct plan "
                            f"execution under chaos")
        except BaseException as exc:
            failures.append((name, repr(exc)))

    threads = [threading.Thread(target=run, args=(n, s))
               for n, s in streams.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
        assert not t.is_alive(), "chaos soak client thread wedged (hang)"
    assert not failures, failures

    m = svc.metrics()                          # server-side: no wire faults
    for name in streams:
        tm = m['tenants'][name]
        # exactly once: every operand completed, none lost, none redone
        assert tm['completed'] == 12 and tm['failed'] == 0, (name, tm)
    stats = m['service']['faults']
    assert stats is not None and plan.total_fired() > 0, stats
    assert stats['service.writer']['fired'] > 0, stats
    assert stats['engine.drainer']['fired'] > 0, stats
    assert plan.skew_s('policy.clock') > 0, "skew never accumulated"
    svc.close(drain=True)
    eng.faults = None
    print(f"PASS case1: 36 chaos-soaked requests exactly-once and "
          f"bit-identical; {plan.total_fired()} faults fired across "
          f"{sum(1 for s in stats.values() if s['fired'])} sites")


# ---------------------------------------------------------------------------
# CASE 2 — fairness: a flood cannot starve an equal-weight tenant
# ---------------------------------------------------------------------------

def case2_fairness_under_flood(eng, plans):
    shape = SHAPES[0]
    victim_reqs = [creq(shape) for _ in range(16)]
    victim_refs = [ref_forward(plans, shape, x) for x in victim_reqs]
    flood_x = creq(shape)
    flood_ref = ref_forward(plans, shape, flood_x)

    eng.set_drainer(watermark=2, max_wait_ms=5.0)
    sock = sock_path(2)
    svc = FFTService(
        engine=eng, persist_policy=False, policy=None,
        max_inflight=256, sched_window=2,
        tenants=[TenantConfig('victim', max_inflight=64),
                 TenantConfig('flood', max_inflight=64)],
    ).start(sock)
    with connect(sock, 'flood') as cf, connect(sock, 'victim') as cv:
        flood_tix = [cf.submit(flood_x) for _ in range(48)]
        victim_tix = [cv.submit(x) for x in victim_reqs]
        for t, ref in zip(victim_tix, victim_refs):
            assert np.array_equal(np.asarray(t.result(timeout=600)), ref)
        # snapshot at the instant the victim's own stream finished:
        # the flood may not have completed more than ~1.5x the victim
        m = svc.metrics()
        done_v = m['tenants']['victim']['completed']
        done_f = m['tenants']['flood']['completed']
        share = done_v / (done_v + done_f)
        assert share >= 0.40, (done_v, done_f, share)
        sched = m['service']['scheduler']
        assert sched['window'] == 2
        assert sched['shares']['victim'] >= 0.40, sched['shares']
        for t in flood_tix:                    # then let the flood drain
            assert np.array_equal(np.asarray(t.result(timeout=600)),
                                  flood_ref)
    svc.close(drain=True)
    print(f"PASS case2: victim completed share {share:.2f} >= 0.40 "
          f"under a 3x flood (victim {done_v}, flood {done_f})")


# ---------------------------------------------------------------------------
# CASE 3 — idempotent resubmit, re-attach, heartbeat reaping
# ---------------------------------------------------------------------------

def case3_idempotent_resubmit(eng, plans):
    shape = SHAPES[0]
    xs = [creq(shape) for _ in range(4)]
    refs = [ref_forward(plans, shape, x) for x in xs]

    eng.set_drainer(watermark=1, max_wait_ms=5.0)
    # scripted: the FIRST result frame (writer hit 1, after HELLO_OK
    # at hit 0) is dropped on the floor
    plan = FaultPlan(points=[FaultPoint('service.writer', 'drop',
                                        at=[1])])
    sock = sock_path(3)
    svc = FFTService(
        engine=eng, persist_policy=False, policy=None, faults=plan,
        heartbeat_timeout_s=1.0,
        tenants=[TenantConfig('idem', max_inflight=16)],
    ).start(sock)

    # -- A: dropped RESULT -> reconnect -> re-delivered, not recomputed
    c1 = FFTClient(sock, tenant='idem')
    [got] = c1.transform([xs[0]], timeout=60.0, deadline_s=60.0)
    assert np.array_equal(np.asarray(got), refs[0])
    assert c1.reconnects == 1, c1.reconnects
    m = svc.metrics()
    d = m['service']['dedup']
    assert d['redelivered'] == 1 and d['hits'] == 1, d
    tm = m['tenants']['idem']
    assert tm['scheduled'] == 1 and tm['completed'] == 1, tm

    # -- B: mid-flight drop -> resubmit re-ATTACHES delivery
    eng.set_drainer(watermark=10**6, max_wait_ms=None)   # hold in queue
    c1.submit(xs[1], key='manual/7')
    deadline = time.monotonic() + 30
    while svc._inflight_total < 1:             # admitted & held
        assert time.monotonic() < deadline
        time.sleep(0.005)
    c1.close()                                 # the submitter vanishes
    c2 = FFTClient(sock, tenant='idem')
    t2 = c2.submit(xs[1], key='manual/7')      # same key: re-attach
    eng.flush()                                # now let it ripen
    assert np.array_equal(np.asarray(t2.result(timeout=60)), refs[1])
    m = svc.metrics()
    assert m['service']['dedup']['reattached'] == 1, m['service']['dedup']
    assert m['tenants']['idem']['scheduled'] == 2, m['tenants']['idem']
    c2.close()
    eng.set_drainer(watermark=1, max_wait_ms=5.0)

    # -- C: idle connections reaped; keepalive clients survive
    c3 = FFTClient(sock, tenant='idem')                    # no heartbeat
    c4 = FFTClient(sock, tenant='idem', heartbeat_s=0.2)   # keepalive
    time.sleep(1.6)                            # > heartbeat_timeout_s
    [g3] = c3.transform([xs[2]], timeout=60.0, deadline_s=60.0)
    assert np.array_equal(np.asarray(g3), refs[2])
    assert c3.reconnects >= 1, "idle connection was never reaped"
    [g4] = c4.transform([xs[3]], timeout=60.0, deadline_s=60.0)
    assert np.array_equal(np.asarray(g4), refs[3])
    assert c4.reconnects == 0, "keepalive client should have survived"
    c3.close()
    c4.close()
    svc.close(drain=True)
    eng.faults = None
    print("PASS case3: dropped RESULT re-delivered (1 dispatch), "
          "mid-flight drop re-attached, idle conn reaped, keepalive "
          "survived")


# ---------------------------------------------------------------------------
# CASE 4 — brownout: breaker trips, sheds batch, recovers
# ---------------------------------------------------------------------------

def case4_brownout(eng, plans):
    shape = SHAPES[0]
    xb, xl = creq(shape), creq(shape)
    rb = ref_forward(plans, shape, xb)
    rl = ref_forward(plans, shape, xl)

    eng.set_drainer(watermark=1, max_wait_ms=2.0)
    # the engine itself retries a blamed group once (retries=1), so a
    # ticket only fails after TWO consecutive dispatch faults: six
    # scripted fires = three consecutive ticket failures
    plan = FaultPlan(points=[FaultPoint('engine.dispatch', 'raise',
                                        at=[0, 1, 2, 3, 4, 5])])
    breaker = BrownoutBreaker(failure_threshold=3, overload_trip=10**6,
                              cooldown_s=0.5, probe_quota=1)
    sock = sock_path(4)
    svc = FFTService(
        engine=eng, persist_policy=False, policy=None, faults=plan,
        brownout=breaker,
        tenants=[TenantConfig('bat', slo='batch', max_inflight=16),
                 TenantConfig('live', slo='interactive', max_inflight=16)],
    ).start(sock)
    with FFTClient(sock, tenant='bat') as cb, \
            FFTClient(sock, tenant='live') as cl:
        for i in range(3):                     # injected dispatch faults
            t = cb.submit(xb, key=f'k{i}')
            try:
                t.result(timeout=60)
                raise AssertionError("injected dispatch fault vanished")
            except RuntimeError as exc:
                assert 'FaultInjected' in str(exc), exc
        # tripped: batch sheds with a typed reason, interactive serves
        try:
            cb.submit(xb).result(timeout=60)
            raise AssertionError("open breaker did not shed batch")
        except RetryAfter as ra:
            assert ra.reason == 'brownout' and ra.retry_after_ms >= 1.0
        assert np.array_equal(
            np.asarray(cl.submit(xl).result(timeout=60)), rl)
        m = svc.metrics()
        br = m['service']['breaker']
        assert br['state'] == 'open', br
        assert br['transitions'].get('closed_to_open') == 1, br
        assert m['tenants']['bat']['rejected'].get('brownout', 0) >= 1
        assert m['tenants']['live']['rejected'] == {}

        time.sleep(0.6)                        # past the cooldown
        # the failed keys were FORGOTTEN (failures are never cached):
        # the same keys now recompute — and the first is the half-open
        # probe whose success closes the breaker
        for i in range(3):
            got = np.asarray(cb.submit(xb, key=f'k{i}').result(timeout=60))
            assert np.array_equal(got, rb), f"k{i} retry not identical"
        m = svc.metrics()
        br = m['service']['breaker']
        assert br['state'] == 'closed', br
        assert br['transitions'].get('open_to_half_open') == 1, br
        assert br['transitions'].get('half_open_to_closed') == 1, br
        assert m['tenants']['bat']['completed'] == 3
        assert m['tenants']['bat']['failed'] == 3
    svc.close(drain=True)
    eng.faults = None
    print("PASS case4: 3 injected dispatch faults tripped the breaker, "
          "batch shed typed 'brownout', interactive served, half-open "
          "probe closed it and the failed keys recomputed bit-identical")


# ---------------------------------------------------------------------------
# CASE 5 — hot tenant-config reload
# ---------------------------------------------------------------------------

def case5_hot_reload(eng, plans):
    shape = SHAPES[0]
    xo, xw = creq(shape), creq(shape)
    ro = ref_forward(plans, shape, xo)
    rw = ref_forward(plans, shape, xw)

    eng.set_drainer(watermark=10**6, max_wait_ms=None)   # hold inflight
    sock = sock_path(5)
    svc = FFTService(
        engine=eng, persist_policy=False, policy=None,
        tenants=[TenantConfig('root', admin=True),
                 TenantConfig('w1'),
                 TenantConfig('old')],
    ).start(sock)
    c_old = FFTClient(sock, tenant='old')
    held = c_old.submit(xo)                    # inflight across the reload
    deadline = time.monotonic() + 30
    while svc._inflight_total < 1:
        assert time.monotonic() < deadline
        time.sleep(0.005)

    with FFTClient(sock, tenant='root') as c_root, \
            FFTClient(sock, tenant='w1') as c_w1:
        new_cfgs = [TenantConfig('root', admin=True),
                    TenantConfig('w1', weight=5.0, max_inflight=32)]
        try:                                   # non-admins are refused
            c_w1.reload(new_cfgs)
            raise AssertionError("non-admin RELOAD accepted")
        except RuntimeError as exc:
            assert 'admin' in str(exc), exc
        ok = c_root.reload(new_cfgs, retire_missing=True)
        assert ok['generation'] == 1, ok
        assert sorted(ok['tenants']) == ['root', 'w1'], ok

        m = svc.metrics()
        assert m['service']['reload_generation'] == 1
        assert m['tenants']['w1']['weight'] == 5.0
        assert m['tenants']['old']['retired'] is True

        # retired: new connections refused, new submits refused ...
        try:
            FFTClient(sock, tenant='old')
            raise AssertionError("retired tenant reconnected")
        except PermissionError as exc:
            assert 'retired' in str(exc), exc
        try:
            c_old.submit(xo).result(timeout=60)
            raise AssertionError("retired tenant submitted")
        except RuntimeError as exc:
            assert 'retired' in str(exc), exc
        # ... but the request admitted BEFORE the reload still serves
        eng.flush()
        assert np.array_equal(np.asarray(held.result(timeout=60)), ro)

        # the re-weighted tenant keeps serving; a second reload bumps
        # the generation again
        eng.set_drainer(watermark=1, max_wait_ms=5.0)
        assert np.array_equal(
            np.asarray(c_w1.submit(xw).result(timeout=60)), rw)
        assert c_root.reload(new_cfgs)['generation'] == 2
    c_old.close()
    svc.close(drain=True)
    print("PASS case5: RELOAD generation 1->2, w1 re-weighted to 5.0, "
          "'old' retired atomically with its inflight request served")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    plans = ref_plans(mesh)
    with FFTEngine(mesh=mesh, max_wait_ms=20.0,
                   schedule_table=None) as eng:
        case1_chaos_soak(eng, plans)
        case2_fairness_under_flood(eng, plans)
        case3_idempotent_resubmit(eng, plans)
        case4_brownout(eng, plans)
        case5_hot_reload(eng, plans)
    print("SERVICE_CHAOS_WORKER_OK")


if __name__ == "__main__":
    main()
