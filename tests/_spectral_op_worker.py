"""Worker script: fused spectral-operator plans on 16 fake devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_spectral_op_worker.py
Exits 0 on success; prints PASS lines per case.

The acceptance contract: ``fft.plan_op`` output is BIT-IDENTICAL to
the unfused composition ``rp.inverse(pw(rp.forward(x), rp.forward(k)))``
with a jitted ``pw`` built on :func:`fft.spectral_mul` — across comm
strategies, wire dtypes (native bitwise; fp16/bf16 bitwise against the
same-wire unfused composition and within wire tolerance of fp32),
kernel tiers, ranks 1-3, real and complex plans, runtime and baked
spectra, batch broadcasting, and overlap pipelining. Plus the serving
integration: operator plans registered on an FFTEngine dispatch as one
coalesced fused group, bitwise equal to direct ``apply``.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft  # noqa: E402

RNG = np.random.default_rng(23)
SHAPES = {1: (1024,), 2: (32, 64), 3: (16, 16, 16)}
STRATEGIES = ("all_to_all", "ppermute", "hierarchical")

#: wire-format tolerance vs the fp32 composition (PR-7 accuracy study:
#: the deviation IS the wire quantization, not a fused-plan artifact)
WIRE_RTOL = {"fp16": 5e-3, "bf16": 3e-2}


@jax.jit
def _pw(y, k):
    """The unfused pointwise stage: spectral_mul on complex spectra,
    jitted so its contraction pinning compiles exactly as the fused
    plan's interior does."""
    re, im = fft.spectral_mul(jnp.real(y), jnp.imag(y),
                              (jnp.real(k), jnp.imag(k)))
    return jax.lax.complex(re, im)


def unfused_real(shape, mesh, x, k, **kw):
    rp = fft.rplan(shape, mesh,
                   padded_spectrum=len(shape) > 1, **kw)
    return np.asarray(rp.inverse(_pw(rp.forward(x), rp.forward(k))))


def unfused_complex(shape, mesh, x, k, **kw):
    p = fft.plan(shape, mesh, **kw)
    return np.asarray(p.inverse(_pw(p.forward(x), p.forward(k))))


def np_conv(x, k, rank):
    axes = tuple(range(-rank, 0))
    return np.fft.irfftn(np.fft.rfftn(x, axes=axes)
                         * np.fft.rfftn(k, axes=axes),
                         s=x.shape[-rank:], axes=axes)


def check_bitwise(name, fused, unfused):
    assert fused.shape == unfused.shape, (name, fused.shape, unfused.shape)
    assert np.array_equal(fused, unfused), (
        f"{name}: fused != unfused, maxerr "
        f"{np.max(np.abs(fused - unfused)):.3e}")
    print(f"PASS {name} bitwise")


def check_strategy_matrix(mesh):
    for rank, shape in SHAPES.items():
        x = RNG.standard_normal(shape).astype(np.float32)
        k = RNG.standard_normal(shape).astype(np.float32)
        want = np_conv(x, k, rank)
        for strategy in STRATEGIES:
            op = fft.plan_op(shape, mesh, op=fft.spectral_mul,
                             real=True, n_spectra=1, comm=strategy)
            got = np.asarray(op.apply(jnp.asarray(x), jnp.asarray(k)))
            assert not np.iscomplexobj(got)
            ref = unfused_real(shape, mesh, jnp.asarray(x), jnp.asarray(k),
                               comm=strategy)
            check_bitwise(f"rank{rank} comm={strategy} real", got, ref)
            err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)),
                                                   1e-30)
            assert err < 3e-4, (rank, strategy, err)
        print(f"PASS rank{rank} fused conv matches numpy")


def check_complex(mesh):
    for rank in (1, 3):
        shape = SHAPES[rank]
        x = (RNG.standard_normal(shape)
             + 1j * RNG.standard_normal(shape)).astype(np.complex64)
        k = (RNG.standard_normal(shape)
             + 1j * RNG.standard_normal(shape)).astype(np.complex64)
        op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=False,
                         n_spectra=1)
        got = np.asarray(op.apply(jnp.asarray(x), jnp.asarray(k)))
        ref = unfused_complex(shape, mesh, jnp.asarray(x), jnp.asarray(k))
        check_bitwise(f"rank{rank} complex", got, ref)
        # planar operands return planar, same bits
        gr, gi = op.apply((jnp.real(x), jnp.imag(x)), jnp.asarray(k))
        assert np.array_equal(np.asarray(gr), got.real)
        assert np.array_equal(np.asarray(gi), got.imag)
        print(f"PASS rank{rank} complex planar form")


def check_baked(mesh):
    for rank in (1, 2):
        shape = SHAPES[rank]
        x = RNG.standard_normal(shape).astype(np.float32)
        k = RNG.standard_normal(shape).astype(np.float32)
        rt = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         n_spectra=1)
        want = np.asarray(rt.apply(jnp.asarray(x), jnp.asarray(k)))
        # 'plan' form: baked through this plan's own forward
        bp = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         spectra=(k,))
        got = np.asarray(bp.apply(jnp.asarray(x)))
        check_bitwise(f"rank{rank} baked(plan) == runtime", got, want)
        for _ in range(3):
            bp.apply(jnp.asarray(x))
        assert bp.bake_count == 1, bp.bake_count
        # 'spectrum' form: np.fft.rfftn-order input, mapped (pure
        # indexing) into the native layout
        ks = np.fft.rfftn(k, axes=tuple(range(-rank, 0)))
        bs = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         spectra=(ks,), spectra_form='spectrum')
        got_s = np.asarray(bs.apply(jnp.asarray(x)))
        err = np.max(np.abs(got_s - want)) / max(np.max(np.abs(want)),
                                                 1e-30)
        assert err < 3e-4, (rank, err)
        print(f"PASS rank{rank} baked(spectrum) rel_err={err:.2e} "
              f"bake_count={bs.bake_count}")


def check_wire_dtypes(mesh):
    shape = SHAPES[2]
    x = RNG.standard_normal(shape).astype(np.float32)
    k = RNG.standard_normal(shape).astype(np.float32)
    fp32 = unfused_real(shape, mesh, jnp.asarray(x), jnp.asarray(k))
    for wd in ("fp16", "bf16"):
        op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         n_spectra=1, wire_dtype=wd)
        got = np.asarray(op.apply(jnp.asarray(x), jnp.asarray(k)))
        ref = unfused_real(shape, mesh, jnp.asarray(x), jnp.asarray(k),
                           wire_dtype=wd)
        check_bitwise(f"wire={wd} vs same-wire unfused", got, ref)
        rel = np.max(np.abs(got - fp32)) / max(np.max(np.abs(fp32)), 1e-30)
        assert rel < WIRE_RTOL[wd], (wd, rel)
        print(f"PASS wire={wd} vs fp32 rel_err={rel:.2e}")


def check_kernel_tiers(mesh):
    shape = SHAPES[2]
    x = RNG.standard_normal(shape).astype(np.float32)
    k = RNG.standard_normal(shape).astype(np.float32)
    for tier in ("reference", "pallas"):
        op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         n_spectra=1, kernel=tier)
        got = np.asarray(op.apply(jnp.asarray(x), jnp.asarray(k)))
        ref = unfused_real(shape, mesh, jnp.asarray(x), jnp.asarray(k),
                           kernel=tier)
        check_bitwise(f"kernel={tier}", got, ref)


def check_batch_broadcast(mesh):
    shape = SHAPES[2]
    xb = RNG.standard_normal((2,) + shape).astype(np.float32)
    k = RNG.standard_normal(shape).astype(np.float32)
    op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                     n_spectra=1)
    got = np.asarray(op.apply(jnp.asarray(xb), jnp.asarray(k)))
    per = np.stack([np.asarray(op.apply(jnp.asarray(xb[i]),
                                        jnp.asarray(k)))
                    for i in range(2)])
    check_bitwise("batched main x unbatched kernel", got, per)
    want = np_conv(xb, k, 2)
    err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-30)
    assert err < 3e-4, err
    print(f"PASS batched conv matches numpy rel_err={err:.2e}")


def check_overlap(mesh):
    shape = SHAPES[3]
    x = RNG.standard_normal(shape).astype(np.float32)
    k = RNG.standard_normal(shape).astype(np.float32)
    base = None
    for oc in (1, 2, 4):
        op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                         n_spectra=1, overlap_chunks=oc)
        got = np.asarray(op.apply(jnp.asarray(x), jnp.asarray(k)))
        if base is None:
            base = got
        assert np.array_equal(base, got), oc
    print("PASS overlap chunks bit-identical across depths")


def check_with_options(mesh):
    shape = SHAPES[2]
    k = RNG.standard_normal(shape).astype(np.float32)
    op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                     spectra=(k,), wire_dtype='native')
    x = RNG.standard_normal(shape).astype(np.float32)
    want = np.asarray(op.apply(jnp.asarray(x)))
    for kw in ({'comm': 'ppermute'}, {'overlap_chunks': 2},
               {'kernel': 'reference'}, {'donate': False}):
        q = op.with_options(**kw)
        assert type(q) is type(op) and q.n_baked == 1, kw
        got = np.asarray(q.apply(jnp.asarray(x)))
        assert np.array_equal(got, want), kw   # pure schedule changes
        print(f"PASS with_options({kw}) round-trips baked op plan")
    w = op.with_options(wire_dtype='fp16')
    assert w.wire_dtype == 'fp16' and w.op_name == op.op_name
    rel = np.max(np.abs(np.asarray(w.apply(jnp.asarray(x))) - want)) \
        / max(np.max(np.abs(want)), 1e-30)
    assert rel < WIRE_RTOL['fp16'], rel
    print(f"PASS with_options(wire_dtype) rebakes, rel_err={rel:.2e}")


def check_serving(mesh):
    from repro.serve.fft_engine import FFTEngine
    shape = SHAPES[2]
    eng = FFTEngine(shape, mesh)
    k = RNG.standard_normal(shape).astype(np.float32)
    eng.register_op('conv', shape=shape, op=fft.spectral_mul,
                    spectra=(k,))
    assert eng.registered_ops() == ['conv']
    plan = eng.plan_for(op='conv')
    xs = [RNG.standard_normal(shape).astype(np.float32) for _ in range(4)]
    tickets = [eng.submit(jnp.asarray(x), op='conv') for x in xs]
    eng.flush()
    for x, t in zip(xs, tickets):
        got = np.asarray(t.result(timeout=60))
        want = np.asarray(plan.apply(jnp.asarray(x)))
        assert np.array_equal(got, want), "served != direct apply"
    stats = eng.dispatch_stats()
    assert stats['groups'] == 1, stats   # one coalesced fused dispatch
    print(f"PASS engine serving: 4 op requests -> {stats['groups']} "
          f"group, bitwise == direct apply")
    # op and plain transform requests never share a group
    t1 = eng.submit(jnp.asarray(xs[0]), op='conv')
    t2 = eng.submit(jnp.asarray(xs[1]), direction='fwd', real=True)
    eng.flush()
    t1.result(timeout=60)
    t2.result(timeout=60)
    assert eng.dispatch_stats()['groups'] == 3
    print("PASS engine serving: op and plain kinds dispatch separately")
    eng.close()


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    check_strategy_matrix(mesh)
    check_complex(mesh)
    check_baked(mesh)
    check_wire_dtypes(mesh)
    check_kernel_tiers(mesh)
    check_batch_broadcast(mesh)
    check_overlap(mesh)
    check_with_options(mesh)
    check_serving(mesh)
    print("SPECTRAL_OP_WORKER_OK")


if __name__ == "__main__":
    main()
