"""Worker script: half-precision wire-format accuracy gate, 16 devices.

Run in a *subprocess* (so the main pytest process keeps 1 device):
    python tests/_wire_accuracy_worker.py
Exits 0 on success; prints PASS lines per case.

On a 4x4 ('x', 'y') mesh, for ranks 1/2/3 under every registered
strategy plus parameterized pod trees:

  * ``wire_dtype='native'`` is BIT-IDENTICAL to a plan that never set
    the knob — the default path must not move;
  * fp16/bf16-wire transforms stay within per-shape max-relative-error
    bounds of the fp32 native-wire output of the SAME plan, forward
    and round trip;
  * real (rfft) plans meet the same gate (the single-real first swap
    and the half-spectrum pair swaps both cast).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import comm  # noqa: E402
import repro.fft as fft  # noqa: E402

RNG = np.random.default_rng(23)

TREES = ('pod_tree:x.2*x.2*y.2*y.2', 'pod_tree:x.4*y.2*y.2')

#: max relative error of a compact-wire transform vs the fp32
#: native-wire output. fp16 keeps an 11-bit significand (~5e-4 per
#: cast, 2-4 casts per schedule); bf16 keeps 8 bits (~8x looser).
#: Observed on this seed: fp16 ~3-4e-4, bf16 ~2-3e-3.
BOUNDS = {
    (4096,): {'fp16': 1.5e-3, 'bf16': 1.2e-2},
    (32, 64): {'fp16': 1.0e-3, 'bf16': 8.0e-3},
    (32, 32, 32): {'fp16': 1.0e-3, 'bf16': 8.0e-3},
}


def relerr(got, want):
    return np.max(np.abs(got - want)) / np.max(np.abs(want))


def check_complex(mesh):
    for shape, bounds in BOUNDS.items():
        z = RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)
        zc = jnp.asarray(z, jnp.complex64)
        for strategy in comm.names() + TREES:
            # donate=False: the same operand feeds every plan below
            base = fft.plan(shape, mesh, comm=strategy, donate=False)
            pnat = fft.plan(shape, mesh, comm=strategy,
                            wire_dtype='native', donate=False)
            ref = np.asarray(base.forward(zc))
            assert np.array_equal(ref, np.asarray(pnat.forward(zc))), (
                shape, strategy, "wire_dtype='native' not bit-identical")
            for wd, bound in bounds.items():
                p = fft.plan(shape, mesh, comm=strategy, wire_dtype=wd,
                             donate=False)
                y = p.forward(zc)
                err = relerr(np.asarray(y, np.complex128), ref)
                assert err <= bound, (shape, strategy, wd, err, bound)
                back = np.asarray(p.inverse(y), np.complex128)
                rerr = relerr(back, z)
                assert rerr <= bound, (shape, strategy, wd,
                                       'roundtrip', rerr, bound)
                print(f"PASS wire {shape} {strategy} {wd} "
                      f"fwd={err:.2e} rt={rerr:.2e} (<= {bound:.0e})")


def check_real(mesh):
    for shape in ((4096,), (32, 32, 32)):
        bounds = BOUNDS[shape]
        x = RNG.standard_normal(shape).astype(np.float32)
        for strategy in ('all_to_all', 'hierarchical', TREES[1]):
            base = fft.rplan(shape, mesh, comm=strategy)
            pnat = fft.rplan(shape, mesh, comm=strategy,
                             wire_dtype='native')
            ref = np.asarray(base.forward(x))
            assert np.array_equal(ref, np.asarray(pnat.forward(x))), (
                shape, strategy, "real native wire not bit-identical")
            for wd, bound in bounds.items():
                p = fft.rplan(shape, mesh, comm=strategy, wire_dtype=wd)
                y = p.forward(x)
                err = relerr(np.asarray(y, np.complex128),
                             ref.astype(np.complex128))
                assert err <= bound, (shape, strategy, wd, err, bound)
                back = np.asarray(p.inverse(y), np.float64)
                rerr = np.max(np.abs(back - x)) / np.max(np.abs(x))
                assert rerr <= bound, (shape, strategy, wd,
                                       'roundtrip', rerr, bound)
                print(f"PASS wire real {shape} {strategy} {wd} "
                      f"fwd={err:.2e} rt={rerr:.2e}")


def main():
    mesh = jax.make_mesh((4, 4), ("x", "y"))
    check_complex(mesh)
    check_real(mesh)
    print("WIRE_WORKER_OK")


if __name__ == "__main__":
    main()
