import os
import sys

# Make `repro` importable regardless of how pytest is invoked. Note: we do
# NOT touch XLA_FLAGS here — tests must see the real (single) device;
# multi-device tests spawn subprocesses that set their own flags.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))
