"""Deliverable (f): every assigned architecture instantiates at reduced
scale and runs one forward + one train step on CPU — output shapes and
no NaNs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, make_batch, smoke_config
from repro.models import model as M
from repro.train.optim import adamw_init, adamw_update

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope='module')
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize('arch', ALL_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = smoke_config(get_config(arch))
    params = M.init_params(rng, cfg, jnp.float32)
    B, S = 2, 24
    batch = make_batch(cfg, batch=B, seq=S, dtype=jnp.float32)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize('arch', ALL_ARCHS)
def test_train_step_no_nan(arch, rng):
    cfg = smoke_config(get_config(arch))
    params = M.init_params(rng, cfg, jnp.float32)
    opt = adamw_init(params)
    B, S = 2, 16
    batch = make_batch(cfg, batch=B, seq=S, dtype=jnp.float32)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt, gnorm = adamw_update(grads, opt, lr=1e-3,
                                          param_dtype=jnp.float32)
        return params, opt, loss, gnorm

    params2, opt2, loss, gnorm = step(params, opt, batch)
    assert bool(jnp.isfinite(loss)), f'{arch}: non-finite loss'
    assert bool(jnp.isfinite(gnorm)), f'{arch}: non-finite grad norm'
    # parameters actually moved
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f'{arch}: optimizer did not update parameters'
    # every leaf finite
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize('arch', ALL_ARCHS)
def test_param_plan_consistency(arch):
    """abstract_params matches init_params shapes/dtypes leaf-for-leaf
    (the dry-run lowers against the abstract tree)."""
    cfg = smoke_config(get_config(arch))
    concrete = M.init_params(jax.random.PRNGKey(1), cfg, jnp.bfloat16)
    abstract = M.abstract_params(cfg, jnp.bfloat16)
    jax.tree.map(lambda c, a: (c.shape, c.dtype) == (a.shape, a.dtype)
                 or pytest.fail(f'{c.shape}/{c.dtype} != {a.shape}/{a.dtype}'),
                 concrete, abstract)
    axes = M.param_axes(cfg)
    jax.tree.map(lambda c, ax: len(c.shape) == len(ax)
                 or pytest.fail(f'{c.shape} vs axes {ax}'), concrete, axes)


def test_full_param_counts_sane():
    """Full (not smoke) configs: parameter counts in the right ballpark
    for the advertised model sizes."""
    expect = {'mamba2-1.3b': (1.0e9, 1.7e9),
              'recurrentgemma-9b': (7e9, 11e9),
              'codeqwen1.5-7b': (6e9, 8.5e9),
              'granite-3-8b': (7e9, 9.5e9),
              'qwen1.5-32b': (29e9, 36e9),
              'internlm2-1.8b': (1.5e9, 2.2e9),
              'hubert-xlarge': (0.8e9, 1.3e9),
              'qwen2-vl-2b': (1.4e9, 2.4e9),
              'deepseek-v2-236b': (210e9, 250e9),
              'dbrx-132b': (120e9, 140e9)}
    for arch, (lo, hi) in expect.items():
        n = M.param_count(get_config(arch))
        assert lo <= n <= hi, f'{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B'


def test_moe_active_params():
    cfg = get_config('deepseek-v2-236b')
    total, active = M.param_count(cfg), M.active_param_count(cfg)
    assert active < 0.2 * total       # top-6 of 160 + shared + attention
    cfg = get_config('dbrx-132b')
    total, active = M.param_count(cfg), M.active_param_count(cfg)
    assert 0.2 * total < active < 0.45 * total   # top-4 of 16
