"""Checkpoint: roundtrip, atomicity, async writer, reshard-on-load."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {'a': jax.random.normal(k, (8, 16), jnp.float32),
            'b': {'c': jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                  'd': jnp.ones((5,), jnp.bfloat16)},
            'step': jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    r = restore_checkpoint(str(tmp_path), 3, t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, r)


def test_latest_and_overwrite(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 5, t)
    save_checkpoint(str(tmp_path), 5, t)          # overwrite is atomic
    assert latest_step(str(tmp_path)) == 5


def test_tmp_dirs_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 2, t)
    os.makedirs(tmp_path / 'step_00000009.tmp')   # simulated torn write
    assert latest_step(str(tmp_path)) == 2


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    for s in (1, 2, 3):
        ck.save(s, _tree(s))
    ck.close()
    assert latest_step(str(tmp_path)) == 3
    r = restore_checkpoint(str(tmp_path), 2, _tree())
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 _tree(2), r)


def test_restore_with_abstract_like(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = restore_checkpoint(str(tmp_path), 1, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, r)
