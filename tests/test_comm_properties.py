"""Properties of the repro.comm redistribution engine.

In-process: plan_swaps minimality (independent BFS distance oracle,
hypothesis-driven when available), cost-model invariants, and the
acceptance check that the cost report for the paper's 512^3/FP32
config reproduces the Table-1 per-superstep cycle structure from
wse_model. The 16-fake-device strategy equivalence / round-trip matrix
runs in a subprocess (see _comm_worker.py)."""
import itertools
import os
import subprocess
import sys

import pytest

from repro import comm
from repro.comm import cost as ccost
from repro.core import plan as planlib
from repro.core import wse_model as wm

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# plan_swaps minimality
# ---------------------------------------------------------------------------

def _bfs_distance(src, dst, axes):
    """Independent oracle: true minimal number of swaps src -> dst."""
    if src == dst:
        return 0
    frontier, seen, d = {src}, {src}, 0
    while frontier:
        d += 1
        nxt = set()
        for st in frontier:
            for ax in axes:
                for mp in planlib.memory_axes(st):
                    st2 = planlib.swap(st, ax, mp)
                    if st2 == dst:
                        return d
                    if st2 not in seen:
                        seen.add(st2)
                        nxt.add(st2)
        frontier = nxt
    raise AssertionError(f"unreachable {src} -> {dst}")


def _all_layouts(ndim, axes):
    out = []
    for owners in itertools.permutations(tuple(axes) + (None,) * ndim, ndim):
        if all(a in owners for a in axes):
            out.append(tuple(owners))
    return sorted(set(out), key=str)


def _check_minimal(src, dst):
    axes = sorted({o for o in src if o is not None}, key=str)
    path = planlib.plan_swaps(src, dst)
    lay = src
    for ax, mp in path:
        assert lay[mp] is None           # every step swaps a memory axis
        lay = planlib.swap(lay, ax, mp)
    assert lay == dst                    # the path reaches dst
    assert len(path) == _bfs_distance(src, dst, axes)   # and is minimal


def test_plan_swaps_minimal_exhaustive_3d():
    layouts = _all_layouts(3, ('x', 'y'))
    for src in layouts:
        for dst in layouts:
            _check_minimal(src, dst)


def test_plan_swaps_minimal_exhaustive_2d():
    layouts = _all_layouts(2, (('x', 'y'),))
    for src in layouts:
        for dst in layouts:
            _check_minimal(src, dst)


def test_plan_swaps_minimal_hypothesis_4d():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    layouts = _all_layouts(4, ('x', 'y'))

    @hyp.given(st.sampled_from(layouts), st.sampled_from(layouts))
    @hyp.settings(deadline=None, max_examples=60)
    def prop(src, dst):
        _check_minimal(src, dst)

    prop()


# ---------------------------------------------------------------------------
# Strategy registry + cost-model invariants
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(comm.names()) >= {'all_to_all', 'ppermute', 'hierarchical'}
    with pytest.raises(ValueError, match='unknown comm strategy'):
        comm.get('nope')
    assert comm.validate('auto') == 'auto'
    # below the plan layer, 'auto' resolves to the default schedule
    assert comm.resolve('auto').name == comm.DEFAULT_STRATEGY
    assert comm.resolve('ppermute').name == 'ppermute'


def test_make_fft_executes_with_auto_comm():
    """A PencilPlan carrying comm='auto' must execute, not just build
    (the executor resolves 'auto' to the default strategy)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.plan import PencilPlan
    from repro.fft import pencil
    mesh = jax.make_mesh((1, 1), ('x', 'y'))
    plan = PencilPlan(shape=(8, 8, 8), mesh=mesh, layout=('x', 'y', None),
                      comm='auto')
    fn, _, _ = pencil.make_fft(plan)
    x = np.random.default_rng(0).standard_normal((8, 8, 8))
    yr, yi = fn(jnp.asarray(x, jnp.float32), jnp.zeros((8, 8, 8), jnp.float32))
    want = np.fft.fftn(x)
    got = np.asarray(yr, np.float64) + 1j * np.asarray(yi, np.float64)
    assert np.max(np.abs(got - want)) / np.max(np.abs(want)) < 3e-4


def test_a2a_cost_is_eq1():
    """The all_to_all strategy cost IS the paper's Eq. 1 at pencil
    granularity: p = n/m devices, elems = n*m^2."""
    st = comm.get('all_to_all')
    for n, m in ((512, 1), (256, 2), (64, 4)):
        p = n // m
        sc = st.cost('x', {'x': p}, n * m * m, 'fp32')
        assert sc.cycles == pytest.approx(wm.tt_comm(n, m, 'fp32'))


def test_cost_orderings():
    """Structural properties the selector relies on: the ring halves
    the wire term but pays per-round launches; the pod-split pays two
    small exchanges instead of one wide one."""
    shape = {'x': 32, 'y': 32}
    for elems in (64, 4096, 1 << 20):
        a2a = comm.get('all_to_all').cost(('x', 'y'), shape, elems, 'fp32')
        ring = comm.get('ppermute').cost(('x', 'y'), shape, elems, 'fp32')
        hier = comm.get('hierarchical').cost(('x', 'y'), shape, elems, 'fp32')
        assert ring.wire_cycles < a2a.wire_cycles
        assert ring.fixed_cycles > a2a.fixed_cycles
        assert hier.p == a2a.p == ring.p == 1024
    # tiny messages: latency-bound -> all_to_all wins over the ring
    small = {s.strategy: s.cycles for s in (
        comm.get(n).cost(('x', 'y'), shape, 32, 'fp32')
        for n in comm.names())}
    assert small['all_to_all'] < small['ppermute']
    # huge messages: wire-bound -> the ring beats the one-shot a2a
    big = {s.strategy: s.cycles for s in (
        comm.get(n).cost(('x', 'y'), shape, 1 << 22, 'fp32')
        for n in comm.names())}
    assert big['ppermute'] < big['all_to_all']


def test_select_paper_config_stays_paper_faithful():
    """At the paper's m=1 single-pencil granularity the broadcast-and-
    filter all_to_all must win (the ring's per-round launches dominate
    its halved wire term)."""
    sel = ccost.select((512,) * 3, ('x', 'y', None), {'x': 512, 'y': 512},
                       precision='fp32')
    assert sel.strategy == 'all_to_all'
    assert sel.overlap_chunks == 1      # m=1: no free local axis to chunk


def test_select_method_matches_registry_rule():
    from repro.fft import methods
    for n in (8, 16, 32, 64, 128, 512, 4096):
        assert ccost.select_method(n, 'fp32') == methods.resolve('auto', n).name
    assert ccost.select_method(12) == 'direct'


# ---------------------------------------------------------------------------
# Acceptance: Table-1 per-superstep structure from the cost report
# ---------------------------------------------------------------------------

def test_cost_report_512_fp32_reproduces_table1_structure():
    pc = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 512, 'y': 512}, precision='fp32',
                                method='stockham', strategy='all_to_all')
    kinds = [s.kind for s in pc.steps]
    assert kinds == ['fft', 'swap', 'fft', 'swap', 'fft']
    for s in pc.steps:
        if s.kind == 'fft':
            assert s.cycles == pytest.approx(wm.pencil_cycles(512, 'fp32'))
        else:
            assert s.cycles == pytest.approx(wm.tt_comm(512, 1, 'fp32'))
    assert pc.serial_cycles == pytest.approx(
        wm.total_cycles_model(512, 1, 'fp32'))
    # same tolerance the model-vs-paper test uses: within 30% of the
    # measured Table-1 cycles, always a lower bound
    meas = wm.TABLE1_CYCLES[512]['fp32']
    assert -0.30 < (pc.serial_cycles - meas) / meas < 0.0
    # the formatted report carries the comparison
    rep = ccost.format_report(pc, (512,) * 3, {'x': 512, 'y': 512})
    assert 'Table 1' in rep and str(meas) in rep


def test_cost_report_via_abstract_mesh_facade():
    """fft.plan on an AbstractMesh prices the paper config without
    devices; .cost_report() is the user-facing acceptance surface."""
    from jax import sharding
    if not hasattr(sharding, 'AbstractMesh'):
        pytest.skip("jax.sharding.AbstractMesh unavailable")
    mesh = sharding.AbstractMesh((('x', 512), ('y', 512)))
    import repro.fft as fft
    p = fft.plan((512,) * 3, mesh, method='stockham', comm='all_to_all')
    pc = p.plan_cost('fp32')
    assert pc.serial_cycles == pytest.approx(
        wm.total_cycles_model(512, 1, 'fp32'))
    rep = p.cost_report('fp32')
    assert 'wse_model' in rep and 'Table 1' in rep


# ---------------------------------------------------------------------------
# 16-device strategy matrix (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_comm_worker_16_devices():
    """Strategy bit-exactness vs the all_to_all reference, redistribute
    round trips for random layouts, the facade matrix under every
    strategy, and overlap-pipeline equivalence — on 16 fake devices."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_comm_worker.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "COMM_WORKER_OK" in proc.stdout
