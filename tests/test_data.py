"""Data pipeline: determinism (the fault-tolerance contract), shift
consistency, packing."""
import numpy as np

from repro.data import SyntheticLM


def test_deterministic_per_step():
    d1 = SyntheticLM(vocab_size=128, seq_len=32, global_batch=4, seed=9)
    d2 = SyntheticLM(vocab_size=128, seq_len=32, global_batch=4, seed=9)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1['tokens'], b2['tokens'])
    np.testing.assert_array_equal(b1['labels'], b2['labels'])
    assert not np.array_equal(d1.batch_at(18)['tokens'], b1['tokens'])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=128, seq_len=32, global_batch=2, seed=1)
    b = d.batch_at(0)
    np.testing.assert_array_equal(b['tokens'][:, 1:], b['labels'][:, :-1])


def test_packing_has_eos_and_range():
    d = SyntheticLM(vocab_size=64, seq_len=256, global_batch=2, seed=2)
    b = d.batch_at(0)
    assert (b['tokens'] == d.eos).any(), 'packed stream should contain EOS'
    assert b['tokens'].min() >= 0 and b['tokens'].max() < 64


def test_embeds_mode():
    d = SyntheticLM(vocab_size=64, seq_len=16, global_batch=2, seed=3,
                    input_mode='embeds', d_model=8, mrope=True)
    b = d.batch_at(0)
    assert b['embeds'].shape == (2, 16, 8)
    assert b['positions'].shape == (3, 2, 16)
    assert 'tokens' not in b
