"""The compatibility shims emit a one-time DeprecationWarning naming
their replacement (and only one — the warning must not spam every
call)."""
import importlib
import warnings

import pytest
import jax.numpy as jnp

from repro.core import _deprecated


def _fresh(name):
    """Make the one-time warning for shim ``name`` fire again."""
    _deprecated.reset(name)


def test_warn_once_is_once():
    _fresh('repro.test.dummy')
    with pytest.warns(DeprecationWarning, match='repro.test.replacement'):
        _deprecated.warn_once('repro.test.dummy', 'repro.test.replacement')
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter('always')
        _deprecated.warn_once('repro.test.dummy', 'repro.test.replacement')
    assert rec == []


def test_core_redistribute_shim_warns():
    import repro.core.redistribute as m
    _fresh('repro.core.redistribute')
    with pytest.warns(DeprecationWarning, match='repro.comm'):
        importlib.reload(m)
    # and the shim still delegates to the engine
    from repro import comm
    assert m.redistribute is comm.redistribute
    assert m.pod_fold is comm.pod_fold


def test_core_distributed_shim_warns():
    import repro.core.distributed as m
    _fresh('repro.core.distributed')
    with pytest.warns(DeprecationWarning, match='repro.fft'):
        importlib.reload(m)


def test_fft1d_entrypoint_warns():
    from repro.core import fft1d
    _fresh('repro.core.fft1d.fft1d')
    re = jnp.zeros((8,), jnp.float32)
    im = jnp.zeros((8,), jnp.float32)
    with pytest.warns(DeprecationWarning, match='repro.fft.methods.apply'):
        fft1d.fft1d(re, im, method='stockham')


def test_ops_pencil_fft_warns():
    from repro.kernels import ops
    _fresh('repro.kernels.ops.pencil_fft')
    re = jnp.zeros((8,), jnp.float32)
    im = jnp.zeros((8,), jnp.float32)
    with pytest.warns(DeprecationWarning, match='repro.fft.methods.apply'):
        ops.pencil_fft(re, im, method='stockham')


def _shim_offenders(pat, exclude_names):
    import pathlib
    import re
    rx = re.compile(pat, re.M)
    root = pathlib.Path(__file__).resolve().parents[1] / 'src' / 'repro'
    return [str(f) for f in root.rglob('*.py')
            if f.name not in exclude_names and rx.search(f.read_text())]


def test_no_internal_module_imports_the_redistribute_shim():
    """Acceptance: no non-shim module imports core.redistribute — the
    engine is repro.comm now."""
    assert not _shim_offenders(
        r'^\s*(from\s+repro\.core\s+import\s+.*\bredistribute\b'
        r'|from\s+repro\.core\.redistribute\s+import'
        r'|import\s+repro\.core\.redistribute)',
        {'redistribute.py'})


def test_no_internal_module_uses_the_other_shims():
    """The warning filters cannot flag internal shim usage (warn_once
    fires once, attributed to the shim module itself), so enforce it
    statically: no src module imports core.distributed or calls the
    deprecated fft1d.fft1d / ops.pencil_fft entry points."""
    assert not _shim_offenders(
        r'^\s*(from\s+repro\.core\s+import\s+.*\bdistributed\b'
        r'|from\s+repro\.core\.distributed\s+import'
        r'|import\s+repro\.core\.distributed)',
        {'distributed.py'})
    assert not _shim_offenders(r'\bfft1d\.fft1d\(', {'fft1d.py'})
    assert not _shim_offenders(r'\bops\.pencil_fft\(|\bpencil_fft\(',
                               {'ops.py'})
