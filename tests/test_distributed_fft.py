"""Distributed wsFFT integration: runs the multi-device worker in a
subprocess with 16 fake host devices (this process keeps 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.mark.slow
def test_distributed_fft_16_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_distributed_fft_worker.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-4000:]}\nSTDERR:\n{r.stderr[-4000:]}"
    assert "ALL DISTRIBUTED FFT TESTS PASSED" in r.stdout
    assert r.stdout.count("PASS") >= 20
