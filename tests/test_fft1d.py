"""Local pencil FFT numerics: every method vs numpy.fft (the paper's own
validation methodology). FFT mathematical properties via hypothesis live
in test_fft1d_properties.py (skipped when hypothesis is not installed).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import fft1d, twiddle as tw

RNG = np.random.default_rng(0)


def _rand(shape):
    return RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)


def _run(x, method, inverse=False, **kw):
    re, im = tw.to_planar(x)
    yr, yi = fft1d.fft1d(re, im, method=method, inverse=inverse, **kw)
    return tw.from_planar((yr, yi))


@pytest.mark.parametrize("n", [4, 8, 16, 64, 256, 1024, 4096])
@pytest.mark.parametrize("method", ["stockham", "four_step", "direct"])
def test_forward_matches_numpy(n, method):
    if method == "direct" and n > 1024:
        pytest.skip("O(n^2) oracle too slow")
    x = _rand((3, n))
    got = _run(x, method)
    want = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [8, 64, 512])
@pytest.mark.parametrize("method", ["stockham", "four_step"])
def test_roundtrip(n, method):
    x = _rand((2, 5, n))
    y = _run(x, method)
    back = _run(y, method, inverse=True)
    np.testing.assert_allclose(back, x, atol=1e-4)


@pytest.mark.parametrize("batch", [(), (1,), (7,), (2, 3)])
def test_batch_shapes(batch):
    n = 64
    x = _rand(batch + (n,))
    got = _run(x, "auto")
    np.testing.assert_allclose(got, np.fft.fft(x, axis=-1), atol=2e-3)


def test_four_step_factor_choices():
    n = 256
    x = _rand((2, n))
    want = np.fft.fft(x, axis=-1)
    for f in [(16, 16), (32, 8), (64, 4), (128, 2)]:
        re, im = tw.to_planar(x)
        yr, yi = fft1d.fft_four_step(re, im, factors=f)
        np.testing.assert_allclose(tw.from_planar((yr, yi)), want, atol=2e-3)


def test_bf16_compute_dtype():
    n = 256
    x = _rand((4, n))
    got = _run(x, "four_step", compute_dtype=jnp.bfloat16)
    want = np.fft.fft(x, axis=-1)
    rel = np.max(np.abs(got - want)) / np.max(np.abs(want))
    assert rel < 5e-2, rel


def test_bad_method():
    re, im = tw.to_planar(_rand((2, 8)))
    with pytest.raises(ValueError):
        fft1d.fft1d(re, im, method="nope")


# ---------------------------------------------------------------------------
# §Perf variants: in-place axis contraction + block-complex four-step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('shape,axis', [
    ((64,), 0), ((4, 128), 1), ((8, 64, 4), 1), ((4, 4, 256), 2),
])
def test_four_step_axis_matches_numpy(shape, axis):
    from repro.core import fft1d as f1
    rng = np.random.default_rng(sum(shape) + axis)
    x = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    re, im = tw.to_planar(x)
    yr, yi = f1.fft_four_step_axis(re, im, axis)
    want = np.fft.fft(x, axis=axis)
    np.testing.assert_allclose(tw.from_planar((yr, yi)), want,
                               atol=1e-4 * np.max(np.abs(want)))
    ir, ii = f1.fft_four_step_axis(yr, yi, axis, inverse=True)
    np.testing.assert_allclose(tw.from_planar((ir, ii)), x, atol=1e-4)


@pytest.mark.parametrize('shape,axis', [
    ((64,), 0), ((4, 128), 1), ((8, 64, 4), 1),
])
def test_four_step_block_matches_numpy(shape, axis):
    """Block-complex path: one real dot per factor, twiddle folded into
    the second-factor matrices (EXPERIMENTS.md §Perf cell A iter 2)."""
    from repro.core import fft1d as f1
    import jax.numpy as jnp
    rng = np.random.default_rng(sum(shape) + axis + 7)
    x = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    re, im = tw.to_planar(x)
    xb = jnp.stack([re, im])
    yb = f1.fft_four_step_block(xb, axis + 1)
    want = np.fft.fft(x, axis=axis)
    np.testing.assert_allclose(tw.from_planar((yb[0], yb[1])), want,
                               atol=1e-4 * np.max(np.abs(want)))
    rb = f1.fft_four_step_block(yb, axis + 1, inverse=True)
    np.testing.assert_allclose(tw.from_planar((rb[0], rb[1])), x, atol=1e-4)
