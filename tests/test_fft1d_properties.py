"""FFT mathematical properties via hypothesis (optional dev dependency;
the whole module is skipped when hypothesis is not installed — the
deterministic numerics coverage lives in test_fft1d.py)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fft1d, twiddle as tw  # noqa: E402

RNG = np.random.default_rng(0)


def _rand(shape):
    return RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)


def _run(x, method, inverse=False, **kw):
    re, im = tw.to_planar(x)
    yr, yi = fft1d.fft1d(re, im, method=method, inverse=inverse, **kw)
    return tw.from_planar((yr, yi))


sizes = st.sampled_from([8, 16, 32, 64, 128])
methods = st.sampled_from(["stockham", "four_step"])


@settings(max_examples=20, deadline=None)
@given(n=sizes, method=methods, data=st.data())
def test_linearity(n, method, data):
    a = data.draw(st.floats(-3, 3, allow_nan=False))
    x, y = _rand((n,)), _rand((n,))
    fx, fy = _run(x, method), _run(y, method)
    fxy = _run(a * x + y, method)
    np.testing.assert_allclose(fxy, a * fx + fy, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=sizes, method=methods)
def test_parseval(n, method):
    x = _rand((n,))
    fx = _run(x, method)
    np.testing.assert_allclose(np.sum(np.abs(fx) ** 2) / n,
                               np.sum(np.abs(x) ** 2), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=sizes, method=methods, data=st.data())
def test_shift_theorem(n, method, data):
    """FFT(roll(x, s))[k] = FFT(x)[k] * exp(-2 pi i s k / n)."""
    s = data.draw(st.integers(0, 7))
    x = _rand((n,))
    lhs = _run(np.roll(x, s), method)
    k = np.arange(n)
    rhs = _run(x, method) * np.exp(-2j * np.pi * s * k / n)
    np.testing.assert_allclose(lhs, rhs, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=sizes)
def test_impulse_response(n):
    """FFT(delta) = ones — catches indexing/permutation bugs exactly."""
    x = np.zeros(n, dtype=complex)
    x[0] = 1.0
    for method in ("stockham", "four_step"):
        np.testing.assert_allclose(_run(x, method), np.ones(n), atol=1e-5)
