"""The repro.fft facade: plan/execute API, rank dispatch, front-ends.

Single-device tests run in-process on a 1x1 mesh (the machinery is the
same shard_map program; collectives just have group size 1). The full
16-fake-device matrix — ranks 1/2/3 x {complex, planar} x {'four_step',
'block'} round trips — runs in a subprocess so this process keeps one
device (see _fft_facade_worker.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.fft as fft
from repro.core import twiddle as tw

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


RNG = np.random.default_rng(3)


def _rand(shape):
    return RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)


@pytest.mark.parametrize("shape", [(256,), (16, 32), (8, 8, 8)])
@pytest.mark.parametrize("method", ["four_step", "block", "stockham"])
def test_roundtrip_complex(mesh, shape, method):
    x = _rand(shape)
    p = fft.plan(shape, mesh, method=method)
    y = p.forward(jnp.asarray(x, jnp.complex64))
    want = np.fft.fftn(x, axes=tuple(range(-len(shape), 0)))
    np.testing.assert_allclose(np.asarray(y, np.complex128), want,
                               atol=3e-4 * np.max(np.abs(want)))
    back = p.inverse(y)
    np.testing.assert_allclose(np.asarray(back, np.complex128), x, atol=1e-4)


@pytest.mark.parametrize("shape", [(256,), (16, 32), (8, 8, 8)])
def test_roundtrip_planar(mesh, shape):
    x = _rand(shape)
    p = fft.plan(shape, mesh)
    re, im = tw.to_planar(x)
    fr, fi = p.forward((re, im))
    want = np.fft.fftn(x, axes=tuple(range(-len(shape), 0)))
    np.testing.assert_allclose(tw.from_planar((fr, fi)), want,
                               atol=3e-4 * np.max(np.abs(want)))
    br, bi = p.inverse((fr, fi))
    np.testing.assert_allclose(tw.from_planar((br, bi)), x, atol=1e-4)


def test_batch_dims_and_cache(mesh):
    p = fft.plan((8, 8), mesh)
    x = _rand((3, 2, 8, 8))
    y = p.forward(jnp.asarray(x, jnp.complex64))
    want = np.fft.fftn(x, axes=(-2, -1))
    np.testing.assert_allclose(np.asarray(y, np.complex128), want,
                               atol=3e-4 * np.max(np.abs(want)))
    # one executable per (direction, batch_shape, dtype, form)
    assert set(p._exec_cache) == {("fwd", (3, 2), "complex64", False)}
    p.forward(jnp.asarray(x, jnp.complex64))
    assert len(p._exec_cache) == 1
    p.inverse(y)
    assert len(p._exec_cache) == 2


def test_plan_validation(mesh):
    with pytest.raises(ValueError, match="unknown FFT method"):
        fft.plan((8, 8), mesh, method="nope")
    with pytest.raises(ValueError, match="ranks 1-3"):
        fft.plan((4, 4, 4, 4), mesh)
    p = fft.plan((8, 8), mesh)
    with pytest.raises(ValueError, match="does not end with"):
        p.forward(jnp.zeros((8, 4), jnp.complex64))
    with pytest.raises(ValueError, match="not a mesh axis"):
        fft.plan((8, 8), mesh, batch_spec="pod")


def test_registry_is_single_source(mesh):
    from repro.core import fft1d
    assert fft.available_methods() == fft1d.METHODS
    assert "block" in fft.available_methods()
    # the legacy shims route through the registry
    x = _rand((4, 64))
    re, im = tw.to_planar(x)
    want = np.fft.fft(x, axis=-1)
    for shim_out in (
        fft1d.fft1d(re, im, method="block"),
        fft.methods.apply(re, im, method="block"),
    ):
        np.testing.assert_allclose(tw.from_planar(shim_out), want, atol=2e-3)


def test_methods_apply_axis_general():
    x = _rand((4, 32, 3))
    re, im = tw.to_planar(x)
    want = np.fft.fft(x, axis=1)
    for method in ("stockham", "four_step", "block"):
        yr, yi = fft.methods.apply(re, im, axis=1, method=method)
        np.testing.assert_allclose(tw.from_planar((yr, yi)), want, atol=2e-3)


@pytest.mark.slow
def test_fft_facade_16_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_fft_facade_worker.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-4000:]}\nSTDERR:\n{r.stderr[-4000:]}"
    assert "ALL FFT FACADE TESTS PASSED" in r.stdout
    assert r.stdout.count("PASS") >= 30
