"""The loop-aware HLO analyzer against hand-computable modules."""
import subprocess
import sys

import pytest

WORKER = r'''
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hlostats

mesh = jax.make_mesh((2, 4), ('x', 'y'))
def f(x, w):
    def body(c, _):
        c = jnp.tanh(c @ w)
        return jax.lax.with_sharding_constraint(
            c, NamedSharding(mesh, P('x', 'y'))), None
    y, _ = jax.lax.scan(body, x, None, length=10)
    return y
x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
with mesh:
    comp = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P('x', None)),
        NamedSharding(mesh, P(None, 'y')))).lower(x, w).compile()
st = hlostats.analyze(comp.as_text())
# per-device dot: (64,256)@(256,64) = 2*64*64*256 flops, 10 iterations
assert st['dot_flops'] == 10 * 2 * 64 * 64 * 256, st['dot_flops']
# all-gather operand: the (64,64) f32 shard, 10 iterations
assert st['collective_bytes']['all-gather'] == 10 * 64 * 64 * 4
assert st['collective_counts']['all-gather'] == 10
assert st['num_partitions'] == 8
print('HLOSTATS_OK')
'''


def test_loop_aware_analysis():
    r = subprocess.run([sys.executable, '-c', WORKER], capture_output=True,
                       text=True, timeout=600)
    assert 'HLOSTATS_OK' in r.stdout, r.stdout + r.stderr


def test_shape_bytes():
    from repro.launch import hlostats as h
    assert h.shape_bytes('f32[2,3]{1,0}') == 24
    assert h.shape_bytes('bf16[128]') == 256
    assert h.shape_bytes('(s32[], f32[4,4])') == 4 + 64
    assert h.shape_bytes('pred[]') == 1
    assert h.shape_bytes('f8e4m3fn[8]') == 8


def test_multiplier_fixpoint_on_synthetic_text():
    from repro.launch import hlostats as h
    text = '''HloModule m, num_partitions=4

%inner.1 (p0: f32[8,8]) -> f32[8,8] {
  %ar = f32[8,8]{1,0} all-reduce(%p0), replica_groups=[1,4]<=[4]
}

%body.2 (p1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %call.1 = f32[8,8]{1,0} call(%gte), to_apply=%inner.1
}

%cond.3 (p2: (s32[], f32[8,8])) -> pred[] {
  %cmp = pred[] compare(%gte2, %c5), direction=LT
}

ENTRY %main.4 (a: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%t), condition=%cond.3, body=%body.2, backend_config={"known_trip_count":{"n":"7"}}
}
'''
    st = h.analyze(text)
    # all-reduce operand 8*8*4 bytes, in a call inside a 7-trip while
    assert st['collective_bytes']['all-reduce'] == 7 * 8 * 8 * 4
