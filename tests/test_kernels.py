"""Pallas kernel correctness: shape/dtype sweeps vs the ref.py oracle,
executed in interpret mode (kernel body evaluated on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import twiddle as tw
from repro.kernels import fft_matmul, fft_pencil, ops, ref

RNG = np.random.default_rng(7)


def _rand(shape):
    return RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("b", [1, 3, 8, 17])
@pytest.mark.parametrize("kernel", ["pencil", "matmul"])
def test_kernel_vs_ref(n, b, kernel):
    x = _rand((b, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    fn = fft_pencil.fft_pencil if kernel == "pencil" else fft_matmul.fft_matmul
    yr, yi = fn(re, im, interpret=True)
    atol = 2e-4 * np.sqrt(n)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=atol)
    np.testing.assert_allclose(np.asarray(yi), np.asarray(wi), atol=atol)


@pytest.mark.parametrize("n", [64, 512])
@pytest.mark.parametrize("kernel", ["pencil", "matmul"])
def test_kernel_inverse_roundtrip(n, kernel):
    x = _rand((5, n))
    re, im = tw.to_planar(x)
    fn = fft_pencil.fft_pencil if kernel == "pencil" else fft_matmul.fft_matmul
    yr, yi = fn(re, im, interpret=True)
    br, bi = fn(yr, yi, inverse=True, interpret=True)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=1e-4)


@pytest.mark.parametrize("block_b", [4, 8, 16])
def test_kernel_block_sizes(block_b):
    """BlockSpec tiling must not change results (incl. padded tail)."""
    n, b = 128, 10
    x = _rand((b, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_pencil.fft_pencil(re, im, block_b=block_b, interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)
    yr, yi = fft_matmul.fft_matmul(re, im, block_b=block_b, interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_kernel_3d_batch_shape():
    n = 64
    x = _rand((2, 3, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_pencil.fft_pencil(re, im, interpret=True)
    assert yr.shape == (2, 3, n)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_matmul_kernel_explicit_factors():
    n = 256
    x = _rand((4, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_matmul.fft_matmul(re, im, factors=(64, 4), interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_ops_dispatch_paths():
    n = 128
    x = _rand((4, n))
    re, im = tw.to_planar(x)
    wr, _ = ref.fft_pencil_ref(re, im)
    for use_kernel in (False, True):
        for method in ("stockham", "four_step", "auto"):
            yr, _ = ops.pencil_fft(re, im, method=method, use_kernel=use_kernel)
            np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_non_pow2_rejected():
    re, im = tw.to_planar(_rand((2, 24)))
    with pytest.raises(ValueError):
        fft_pencil.fft_pencil(re, im, interpret=True)


# ---------------------------------------------------------------------------
# Block-complex kernel (EXPERIMENTS.md §Perf cell A winner)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('n', [64, 256, 1024])
@pytest.mark.parametrize('batch', [(1,), (3,), (2, 5)])
@pytest.mark.parametrize('inverse', [False, True])
def test_fft_block_kernel_vs_oracle(n, batch, inverse):
    from repro.core import fft1d as f1
    from repro.kernels.fft_block import fft_block
    rng = np.random.default_rng(n + sum(batch))
    x = rng.standard_normal((2,) + batch + (n,)).astype(np.float32)
    xj = jnp.asarray(x)
    got = fft_block(xj, inverse=inverse, interpret=True)
    want = f1.fft_four_step_block(xj, xj.ndim - 1, inverse=inverse)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_fft_block_kernel_vs_numpy():
    from repro.kernels.fft_block import fft_block
    rng = np.random.default_rng(0)
    n = 512
    z = rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n))
    x = jnp.stack([jnp.asarray(z.real, jnp.float32),
                   jnp.asarray(z.imag, jnp.float32)])
    y = fft_block(x, interpret=True)
    got = np.asarray(y[0]) + 1j * np.asarray(y[1])
    want = np.fft.fft(z, axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-3)
