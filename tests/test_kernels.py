"""Pallas kernel correctness: shape/dtype sweeps vs the ref.py oracle,
executed in interpret mode (kernel body evaluated on CPU), plus the
kernel-tier dispatch contract (fused superstep kernel bit-identical to
the jnp reference; per-backend 'auto' resolution)."""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft1d as _f1
from repro.core import twiddle as tw
from repro.fft import methods
from repro.kernels import fft_fused, fft_matmul, fft_pencil, ops, ref

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

RNG = np.random.default_rng(7)


def _rand(shape):
    return RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("b", [1, 3, 8, 17])
@pytest.mark.parametrize("kernel", ["pencil", "matmul"])
def test_kernel_vs_ref(n, b, kernel):
    x = _rand((b, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    fn = fft_pencil.fft_pencil if kernel == "pencil" else fft_matmul.fft_matmul
    yr, yi = fn(re, im, interpret=True)
    atol = 2e-4 * np.sqrt(n)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=atol)
    np.testing.assert_allclose(np.asarray(yi), np.asarray(wi), atol=atol)


@pytest.mark.parametrize("n", [64, 512])
@pytest.mark.parametrize("kernel", ["pencil", "matmul"])
def test_kernel_inverse_roundtrip(n, kernel):
    x = _rand((5, n))
    re, im = tw.to_planar(x)
    fn = fft_pencil.fft_pencil if kernel == "pencil" else fft_matmul.fft_matmul
    yr, yi = fn(re, im, interpret=True)
    br, bi = fn(yr, yi, inverse=True, interpret=True)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=1e-4)


@pytest.mark.parametrize("block_b", [4, 8, 16])
def test_kernel_block_sizes(block_b):
    """BlockSpec tiling must not change results (incl. padded tail)."""
    n, b = 128, 10
    x = _rand((b, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_pencil.fft_pencil(re, im, block_b=block_b, interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)
    yr, yi = fft_matmul.fft_matmul(re, im, block_b=block_b, interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_kernel_3d_batch_shape():
    n = 64
    x = _rand((2, 3, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_pencil.fft_pencil(re, im, interpret=True)
    assert yr.shape == (2, 3, n)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_matmul_kernel_explicit_factors():
    n = 256
    x = _rand((4, n))
    re, im = tw.to_planar(x)
    wr, wi = ref.fft_pencil_ref(re, im)
    yr, yi = fft_matmul.fft_matmul(re, im, factors=(64, 4), interpret=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_ops_dispatch_paths():
    n = 128
    x = _rand((4, n))
    re, im = tw.to_planar(x)
    wr, _ = ref.fft_pencil_ref(re, im)
    for use_kernel in (False, True):
        for method in ("stockham", "four_step", "auto"):
            yr, _ = ops.pencil_fft(re, im, method=method, use_kernel=use_kernel)
            np.testing.assert_allclose(np.asarray(yr), np.asarray(wr), atol=2e-3)


def test_non_pow2_rejected():
    re, im = tw.to_planar(_rand((2, 24)))
    with pytest.raises(ValueError):
        fft_pencil.fft_pencil(re, im, interpret=True)


# ---------------------------------------------------------------------------
# Block-complex kernel (EXPERIMENTS.md §Perf cell A winner)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('n', [64, 256, 1024])
@pytest.mark.parametrize('batch', [(1,), (3,), (2, 5)])
@pytest.mark.parametrize('inverse', [False, True])
def test_fft_block_kernel_vs_oracle(n, batch, inverse):
    from repro.core import fft1d as f1
    from repro.kernels.fft_block import fft_block
    rng = np.random.default_rng(n + sum(batch))
    x = rng.standard_normal((2,) + batch + (n,)).astype(np.float32)
    xj = jnp.asarray(x)
    got = fft_block(xj, inverse=inverse, interpret=True)
    want = f1.fft_four_step_block(xj, xj.ndim - 1, inverse=inverse)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_fft_block_kernel_vs_numpy():
    from repro.kernels.fft_block import fft_block
    rng = np.random.default_rng(0)
    n = 512
    z = rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n))
    x = jnp.stack([jnp.asarray(z.real, jnp.float32),
                   jnp.asarray(z.imag, jnp.float32)])
    y = fft_block(x, interpret=True)
    got = np.asarray(y[0]) + 1j * np.asarray(y[1])
    want = np.fft.fft(z, axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-3)


# ---------------------------------------------------------------------------
# Kernel tier: fused twiddle+transpose superstep + per-backend dispatch
# ---------------------------------------------------------------------------

def _bitwise(got, want, name):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, f"{name}: {got.shape} != {want.shape}"
    assert np.array_equal(got, want), (
        f"{name}: max abs diff {np.max(np.abs(got - want)):.3e} "
        "(not bitwise)")


@pytest.mark.parametrize("n", [16, 64, 256])
@pytest.mark.parametrize("b", [5, 8, 17])
@pytest.mark.parametrize("inverse", [False, True])
@pytest.mark.parametrize("with_w", [False, True])
def test_fused_kernel_bitwise_vs_reference(n, b, inverse, with_w):
    """Interpret-mode fused kernel == jitted jnp reference, bit for bit
    (incl. batch remainders that don't divide block_b)."""
    re, im = tw.to_planar(_rand((b, n)))
    wr = wi = None
    if with_w:
        wr, wi = tw.to_planar(_rand((b, n)))
    want = jax.jit(functools.partial(
        _f1.fft_twiddle_transpose, inverse=inverse))(re, im, wr, wi)
    got = fft_fused.fft_twiddle_transpose(re, im, wr, wi, inverse=inverse,
                                          interpret=True)
    assert got[0].shape == (n, b)
    for g, w, nm in zip(got, want, ("re", "im")):
        _bitwise(g, w, f"fused n={n} b={b} inv={inverse} w={with_w} {nm}")


def test_fused_kernel_lead_dims_and_broadcast_twiddle():
    """Lead dims vectorize over the grid; a (1, n)-broadcast twiddle is
    accepted like the jnp reference accepts it."""
    n, b = 64, 6
    re, im = tw.to_planar(_rand((2, 3, b, n)))
    wr, wi = tw.to_planar(_rand((1, n)))
    want = jax.jit(_f1.fft_twiddle_transpose)(re, im, wr, wi)
    got = fft_fused.fft_twiddle_transpose(re, im, wr, wi, interpret=True)
    assert got[0].shape == (2, 3, n, b)
    for g, w, nm in zip(got, want, ("re", "im")):
        _bitwise(g, w, f"fused lead-dims {nm}")


def test_fused_kernel_rejects_rank1():
    re, im = tw.to_planar(_rand((32,)))
    with pytest.raises(ValueError):
        fft_fused.fft_twiddle_transpose(re, im, interpret=True)


def test_resolve_kernel_per_backend():
    st = methods.resolve("stockham", 64)
    assert methods.resolve_kernel("reference", st, "cpu") == "reference"
    assert methods.resolve_kernel("pallas", st, "cpu") == "pallas"
    # 'auto' takes the Pallas tier only where it lowers natively
    assert methods.resolve_kernel("auto", st, "cpu") == "reference"
    assert methods.resolve_kernel("auto", st, "gpu") == "pallas"
    assert methods.resolve_kernel("auto", st, "cuda") == "pallas"
    assert methods.resolve_kernel("auto", st, "tpu") == "pallas"
    assert methods.resolve_kernel("auto", st, "mystery") == "reference"
    # a method without a kernel for the backend always falls back
    direct = methods.resolve("direct", 24)
    assert methods.resolve_kernel("pallas", direct, "tpu") == "reference"
    assert methods.resolve_kernel("auto", direct, "gpu") == "reference"
    with pytest.raises(ValueError):
        methods.resolve_kernel("mosaic", st)


def test_default_interpret_env_override(monkeypatch):
    monkeypatch.delenv(methods.KERNEL_INTERPRET_ENV, raising=False)
    assert methods.default_interpret("cpu") is True
    assert methods.default_interpret("gpu") is False
    assert methods.default_interpret("tpu") is False
    monkeypatch.setenv(methods.KERNEL_INTERPRET_ENV, "1")
    assert methods.default_interpret("tpu") is True
    monkeypatch.setenv(methods.KERNEL_INTERPRET_ENV, "0")
    assert methods.default_interpret("cpu") is False
    monkeypatch.setenv(methods.KERNEL_INTERPRET_ENV, "")
    assert methods.default_interpret("cpu") is True


@pytest.mark.parametrize("inverse", [False, True])
def test_apply_pallas_tier_bitwise_stockham(inverse):
    """methods.apply kernel='pallas' (interpret) == kernel='reference',
    both jitted — the contract the distributed plans rely on."""
    n = 128
    re, im = tw.to_planar(_rand((6, n)))
    tiers = {
        t: jax.jit(functools.partial(methods.apply, method="stockham",
                                     kernel=t, inverse=inverse))(re, im)
        for t in ("reference", "pallas")
    }
    for g, w, nm in zip(tiers["pallas"], tiers["reference"], ("re", "im")):
        _bitwise(g, w, f"apply stockham inv={inverse} {nm}")


@pytest.mark.parametrize("method", ["four_step", "block"])
def test_apply_pallas_tier_allclose(method):
    """Non-stockham kernels use different op orders — allclose, not
    bitwise."""
    n = 256
    re, im = tw.to_planar(_rand((4, n)))
    ref_out = methods.apply(re, im, method=method, kernel="reference")
    pal_out = methods.apply(re, im, method=method, kernel="pallas")
    for g, w in zip(pal_out, ref_out):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=2e-3)


def test_apply_auto_tier_is_reference_on_cpu():
    n = 64
    re, im = tw.to_planar(_rand((3, n)))
    auto = jax.jit(functools.partial(methods.apply, method="stockham",
                                     kernel="auto"))(re, im)
    ref_out = jax.jit(functools.partial(methods.apply, method="stockham",
                                        kernel="reference"))(re, im)
    for g, w, nm in zip(auto, ref_out, ("re", "im")):
        _bitwise(g, w, f"apply auto==reference {nm}")


@pytest.mark.slow
def test_kernel_tier_16_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_kernel_tier_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert r.returncode == 0, (
        f"STDOUT:\n{r.stdout[-4000:]}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "KERNEL_TIER_WORKER_OK" in r.stdout
    assert r.stdout.count("PASS") >= 18
