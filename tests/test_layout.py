"""Layout algebra of the pencil decomposition: schedules, swap planning,
and invariants. These run with a single device — pure symbolic checks of
the redistribution engine's bookkeeping. Hypothesis-based invariants live
in test_layout_properties.py (skipped without hypothesis).

Schedules are imported from repro.fft.pencil (their home); the
core.distributed deprecation shim is checked to re-export them."""
import pytest

from repro.core import plan as planlib
from repro.fft import pencil as dist


def test_forward_schedule_3d_matches_paper():
    """Paper §4.2: z-FFT, row transpose (x<->z), x-FFT, column transpose
    (x<->y), y-FFT."""
    steps, final = dist.forward_schedule(('x', 'y', None))
    assert steps == (('fft', 2), ('swap', 'x', 2), ('fft', 0),
                     ('swap', 'y', 0), ('fft', 1))
    assert final == ('y', None, 'x')


def test_forward_schedule_2d():
    steps, final = dist.forward_schedule((('x', 'y'), None))
    assert steps == (('fft', 1), ('swap', ('x', 'y'), 1), ('fft', 0))
    assert final == (None, ('x', 'y'))


def test_inverse_schedule_mirrors_forward():
    ins, final = dist.inverse_schedule(('x', 'y', None))
    assert final == ('x', 'y', None)
    # reverse superstep order: y, swap, x, swap, z
    assert [s[0] for s in ins] == ['fft', 'swap', 'fft', 'swap', 'fft']
    assert ins[0] == ('fft', 1)
    assert ins[-1] == ('fft', 2)


def test_swap_algebra():
    lay = ('x', 'y', None)
    lay2 = planlib.swap(lay, 'x', 2)
    assert lay2 == (None, 'y', 'x')
    lay3 = planlib.swap(lay2, 'y', 0)
    assert lay3 == ('y', None, 'x')
    with pytest.raises(ValueError):
        planlib.swap(lay, 'x', 0)  # pos 0 is not a memory axis


def test_plan_swaps_roundtrip():
    src = ('x', 'y', None)
    dst = ('y', None, 'x')
    path = planlib.plan_swaps(src, dst)
    lay = src
    for ax, mp in path:
        lay = planlib.swap(lay, ax, mp)
    assert lay == dst
    assert planlib.plan_swaps(src, src) == ()


def test_plan_local_shape_and_validate():
    import jax
    mesh = jax.make_mesh((1, 1), ('x', 'y'))
    p = planlib.make_fft3d_plan(8, mesh)
    p.validate()
    assert p.local_shape() == (8, 8, 8)


def test_distributed_shim_reexports():
    """core.distributed stays importable and points at repro.fft."""
    from repro.core import distributed as shim
    assert shim.make_fft is dist.make_fft
    assert shim.forward_schedule is dist.forward_schedule
    from repro.fft import large1d
    assert shim.make_fft1d_large is large1d.make_fft1d_large
