"""Layout algebra of the pencil decomposition: schedules, swap planning,
and invariants (property-based). These run with a single device — pure
symbolic checks of the redistribution engine's bookkeeping."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import distributed as dist
from repro.core import plan as planlib


def test_forward_schedule_3d_matches_paper():
    """Paper §4.2: z-FFT, row transpose (x<->z), x-FFT, column transpose
    (x<->y), y-FFT."""
    steps, final = dist.forward_schedule(('x', 'y', None))
    assert steps == (('fft', 2), ('swap', 'x', 2), ('fft', 0),
                     ('swap', 'y', 0), ('fft', 1))
    assert final == ('y', None, 'x')


def test_forward_schedule_2d():
    steps, final = dist.forward_schedule((('x', 'y'), None))
    assert steps == (('fft', 1), ('swap', ('x', 'y'), 1), ('fft', 0))
    assert final == (None, ('x', 'y'))


def test_inverse_schedule_mirrors_forward():
    ins, final = dist.inverse_schedule(('x', 'y', None))
    assert final == ('x', 'y', None)
    # reverse superstep order: y, swap, x, swap, z
    assert [s[0] for s in ins] == ['fft', 'swap', 'fft', 'swap', 'fft']
    assert ins[0] == ('fft', 1)
    assert ins[-1] == ('fft', 2)


def test_swap_algebra():
    lay = ('x', 'y', None)
    lay2 = planlib.swap(lay, 'x', 2)
    assert lay2 == (None, 'y', 'x')
    lay3 = planlib.swap(lay2, 'y', 0)
    assert lay3 == ('y', None, 'x')
    with pytest.raises(ValueError):
        planlib.swap(lay, 'x', 0)  # pos 0 is not a memory axis


def test_plan_swaps_roundtrip():
    src = ('x', 'y', None)
    dst = ('y', None, 'x')
    path = planlib.plan_swaps(src, dst)
    lay = src
    for ax, mp in path:
        lay = planlib.swap(lay, ax, mp)
    assert lay == dst
    assert planlib.plan_swaps(src, src) == ()


def test_plan_local_shape_and_validate():
    import jax
    mesh = jax.make_mesh((1, 1), ('x', 'y'))
    p = planlib.make_fft3d_plan(8, mesh)
    p.validate()
    assert p.local_shape() == (8, 8, 8)


# property: any forward schedule transforms every axis exactly once and
# the inverse schedule ends at the original layout.
layouts = st.permutations(['x', 'y', None]).map(tuple)


@settings(max_examples=30, deadline=None)
@given(lay=layouts)
def test_schedules_cover_all_axes(lay):
    steps, final = dist.forward_schedule(lay)
    ffts = [s[1] for s in steps if s[0] == 'fft']
    assert sorted(ffts) == [0, 1, 2]
    ins, back = dist.inverse_schedule(lay)
    assert back == lay
    assert sorted(s[1] for s in ins if s[0] == 'fft') == [0, 1, 2]


@settings(max_examples=30, deadline=None)
@given(lay=layouts, data=st.data())
def test_plan_swaps_reaches_any_reachable_layout(lay, data):
    """BFS planner: applying random swaps yields a layout the planner can
    reach back from."""
    cur = lay
    for _ in range(data.draw(st.integers(0, 3))):
        mems = planlib.memory_axes(cur)
        axes = [o for o in cur if o is not None]
        if not mems or not axes:
            return
        ax = data.draw(st.sampled_from(axes))
        mp = data.draw(st.sampled_from(list(mems)))
        cur = planlib.swap(cur, ax, mp)
    path = planlib.plan_swaps(cur, lay)
    for ax, mp in path:
        cur = planlib.swap(cur, ax, mp)
    assert cur == lay
