"""Hypothesis invariants of the layout algebra (optional dev dependency;
skipped when hypothesis is not installed — deterministic layout coverage
lives in test_layout.py)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan as planlib  # noqa: E402
from repro.fft import pencil as dist  # noqa: E402

# property: any forward schedule transforms every axis exactly once and
# the inverse schedule ends at the original layout.
layouts = st.permutations(['x', 'y', None]).map(tuple)


@settings(max_examples=30, deadline=None)
@given(lay=layouts)
def test_schedules_cover_all_axes(lay):
    steps, final = dist.forward_schedule(lay)
    ffts = [s[1] for s in steps if s[0] == 'fft']
    assert sorted(ffts) == [0, 1, 2]
    ins, back = dist.inverse_schedule(lay)
    assert back == lay
    assert sorted(s[1] for s in ins if s[0] == 'fft') == [0, 1, 2]


@settings(max_examples=30, deadline=None)
@given(lay=layouts, data=st.data())
def test_plan_swaps_reaches_any_reachable_layout(lay, data):
    """BFS planner: applying random swaps yields a layout the planner can
    reach back from."""
    cur = lay
    for _ in range(data.draw(st.integers(0, 3))):
        mems = planlib.memory_axes(cur)
        axes = [o for o in cur if o is not None]
        if not mems or not axes:
            return
        ax = data.draw(st.sampled_from(axes))
        mp = data.draw(st.sampled_from(list(mems)))
        cur = planlib.swap(cur, ax, mp)
    path = planlib.plan_swaps(cur, lay)
    for ax, mp in path:
        cur = planlib.swap(cur, ax, mp)
    assert cur == lay
