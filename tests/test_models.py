"""Model-layer oracles: every fused/chunked implementation against its
naive reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import griffin, layers, moe, ssd
from repro.configs import get_config, smoke_config


# ---------------------------------------------------------------------------
# flash attention vs naive
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal, window=0, q_offset=0):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum('bqhd,bkhd->bhqk', q, kk) * (D ** -0.5)
    qpos = q_offset + np.arange(Sq)
    kpos = np.arange(k.shape[1])
    m = np.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(jnp.asarray(m)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum('bhqk,bkhd->bqhd', p, vv)


@pytest.mark.parametrize('skv,h,kh,window,causal', [
    (64, 4, 4, 0, True), (64, 4, 2, 0, True), (128, 8, 1, 0, True),
    (96, 4, 2, 24, True), (64, 4, 4, 0, False), (128, 4, 2, 16, True),
])
def test_flash_vs_naive(skv, h, kh, window, causal):
    key = jax.random.PRNGKey(skv * h + kh)
    B, D = 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, skv, h, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, skv, kh, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, skv, kh, D), jnp.float32)
    got = A.flash_attention(q, k, v, causal=causal, window=window, chunk=32)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_decode_vs_naive():
    """Sq=1 with kv_len masking (ragged decode)."""
    key = jax.random.PRNGKey(7)
    B, S, H, KH, D = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    L = 40
    got = A.flash_attention(q, k, v, causal=True, q_offset=L - 1,
                            kv_len=jnp.int32(L), chunk=S)
    want = naive_attention(q, k[:, :L], v[:, :L], causal=True, q_offset=L - 1)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD chunked scan vs sequential recurrence
# ---------------------------------------------------------------------------

def naive_ssd(xh, b, c, dt, a_log):
    """h_t = a_t h + dt_t B_t x_t^T ; y_t = C_t.h_t  (G=1)."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    Ac = -np.exp(np.asarray(a_log, np.float64))
    x = np.asarray(xh, np.float64)
    bb = np.asarray(b, np.float64)[:, :, 0]
    cc = np.asarray(c, np.float64)[:, :, 0]
    dtf = np.asarray(dt, np.float64)
    y = np.zeros((B, S, H, P))
    h = np.zeros((B, H, N, P))
    for t in range(S):
        a = np.exp(dtf[:, t] * Ac)                        # (B,H)
        h = h * a[..., None, None] + \
            dtf[:, t][..., None, None] * bb[:, t][:, None, :, None] \
            * x[:, t][:, :, None, :]
        y[:, t] = np.einsum('bi,bhip->bhp', cc[:, t], h)
    return y, h


@pytest.mark.parametrize('s,chunk', [(32, 8), (40, 16), (16, 16)])
def test_ssd_chunked_vs_sequential(s, chunk):
    key = jax.random.PRNGKey(3)
    B, H, P, N = 2, 4, 8, 16
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, s, H, P), jnp.float32)
    b = jax.random.normal(ks[1], (B, s, 1, N), jnp.float32) * 0.5
    c = jax.random.normal(ks[2], (B, s, 1, N), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, s, H), jnp.float32))
    a_log = jax.random.uniform(ks[4], (H,), jnp.float32, 0.0, 1.5)
    y, h = ssd._ssd_chunk_scan(xh, b, c, dt, a_log, chunk)
    y_ref, h_ref = naive_ssd(xh, b, c, dt, a_log)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)


def test_ssd_decode_matches_prefill():
    """Sequential ssd_decode steps == chunked full-sequence states."""
    cfg = smoke_config(get_config('mamba2-1.3b'))
    p = layers.init_from_plan(jax.random.PRNGKey(0), ssd.ssd_plan(cfg),
                              jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    full, cache = ssd.ssd_apply(p, cfg, x, return_cache=True)
    di, H, P, N = ssd.ssd_dims(cfg)
    dec_cache = {'state': jnp.zeros((B, H, N, P), jnp.float32),
                 'conv_x': jnp.zeros((B, cfg.conv_width - 1, di), jnp.float32),
                 'conv_b': jnp.zeros((B, cfg.conv_width - 1, N), jnp.float32),
                 'conv_c': jnp.zeros((B, cfg.conv_width - 1, N), jnp.float32)}
    outs = []
    for t in range(S):
        o, dec_cache = ssd.ssd_decode(p, cfg, x[:, t:t + 1], dec_cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(dec_cache['state'], cache['state'],
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU chunked scan vs sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('s,chunk', [(24, 8), (30, 16)])
def test_lru_scan_chunked(s, chunk):
    key = jax.random.PRNGKey(5)
    B, W = 2, 8
    a = jax.nn.sigmoid(jax.random.normal(key, (B, s, W)))
    b = jax.random.normal(jax.random.PRNGKey(6), (B, s, W))
    h0 = jax.random.normal(jax.random.PRNGKey(7), (B, W))
    hs, hf = griffin._lru_scan_chunked(a, b, h0, chunk)
    h = np.asarray(h0, np.float64)
    for t in range(s):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        np.testing.assert_allclose(hs[:, t], h, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hf, h, atol=1e-4, rtol=1e-4)


def test_rglru_decode_matches_prefill():
    cfg = smoke_config(get_config('recurrentgemma-9b'))
    p = layers.init_from_plan(jax.random.PRNGKey(0), griffin.rglru_plan(cfg),
                              jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    full, cache = griffin.rglru_apply(p, cfg, x, return_cache=True)
    dec = {'h': jnp.zeros((B, cfg.lru_width), jnp.float32),
           'conv': jnp.zeros((B, cfg.conv_width - 1, cfg.lru_width),
                             jnp.float32)}
    outs = []
    for t in range(S):
        o, dec = griffin.rglru_decode(p, cfg, x[:, t:t + 1], dec)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(dec['h'], cache['h'], atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

def test_moe_no_drop_equals_dense():
    """With capacity >= all assignments, scatter-dispatch MoE equals the
    dense gate-weighted mixture."""
    cfg = dataclasses.replace(smoke_config(get_config('dbrx-132b')),
                              capacity_factor=8.0, num_shared_experts=0)
    p = layers.init_from_plan(jax.random.PRNGKey(0), moe.moe_plan(cfg),
                              jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    y, aux = moe.moe_apply(p, cfg, x)
    gates, idx, probs = moe.route(p['router'], x, cfg)
    dense = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        wi, wo = p['wi'][e], p['wo'][e]
        h = x @ wi
        g, u = jnp.split(h, 2, axis=-1)
        out_e = (jax.nn.silu(g) * u) @ wo
        w_e = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1)
        dense += out_e * w_e[..., None]
    np.testing.assert_allclose(y, dense, atol=1e-4, rtol=1e-4)
    assert float(aux) > 0.5          # load-balance loss is O(1)


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens drop, but outputs stay finite and within
    the span of expert outputs (no garbage from the drop slot)."""
    cfg = dataclasses.replace(smoke_config(get_config('deepseek-v2-236b')),
                              capacity_factor=1.0)
    p = layers.init_from_plan(jax.random.PRNGKey(0), moe.moe_plan(cfg),
                              jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y, aux = moe.moe_apply(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_dispatch_indices_invariants():
    """Capacity accounting: per expert, kept slots are unique and
    in-order; dropped entries all map to the overflow slot."""
    idx = jnp.asarray(np.random.default_rng(0).integers(0, 4, (32, 2)),
                      jnp.int32)
    E, C = 4, 8
    order, dest, keep = moe._dispatch_indices(idx, E, C)
    dest = np.asarray(dest)
    keep = np.asarray(keep)
    assert dest[keep].size == len(set(dest[keep].tolist()))   # unique slots
    assert np.all(dest[~keep] == E * C)
    counts = np.bincount(np.asarray(idx).reshape(-1), minlength=E)
    kept_per_e = np.bincount(dest[keep] // C, minlength=E)
    np.testing.assert_array_equal(kept_per_e, np.minimum(counts, C))


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)[None]
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = layers.apply_rope(q, jnp.asarray([[m]]))
        kn = layers.apply_rope(k, jnp.asarray([[n]]))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_mrope_equals_rope_when_streams_equal():
    """If t/h/w position streams coincide, M-RoPE == standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 2, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 6))
    got = layers.apply_mrope(x, pos3, sections=(2, 3, 3))
    want = layers.apply_rope(x, pos)
    np.testing.assert_allclose(got, want, atol=1e-5)
