"""Multi-device (fake 8-CPU-device) integration via subprocess — the
same distribution code paths (FSDP + TP + EP + SP collectives) the
production meshes use, executed for real on a 2x4 mesh."""
import subprocess
import sys

import pytest

TRAIN_WORKER = r'''
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, smoke_config, make_batch
from repro.models import model as M
from repro.train.optim import adamw_init
from repro.train.trainstep import jit_train_step

for arch in ('internlm2-1.8b', 'dbrx-132b', 'mamba2-1.3b'):
    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    sds = jax.ShapeDtypeStruct
    B, S = 4, 16
    b_sds = {'tokens': sds((B, S), jnp.int32), 'labels': sds((B, S), jnp.int32)}
    b_ax = {'tokens': ('batch', 'seq'), 'labels': ('batch', 'seq')}
    with mesh:
        step, aux = jit_train_step(cfg, mesh, b_sds, b_ax, microbatches=2,
                                   param_dtype=jnp.float32)
        params = jax.device_put(
            M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32), aux['p_sh'])
        opt = jax.device_put(adamw_init(params), aux['o_sh'])
        batch = make_batch(cfg, batch=B, seq=S, dtype=jnp.float32)
        batch = {k: jax.device_put(v, aux['b_sh'][k]) for k, v in batch.items()
                 if k in b_sds}
        params, opt, m = step(params, opt, batch)
        loss = float(m['loss'])
        assert np.isfinite(loss), (arch, loss)
        print(f'MD_TRAIN_OK {arch} {loss:.4f}')
'''

SP_WORKER = r'''
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import attention as A

mesh = jax.make_mesh((2, 4), ('data', 'model'))
B, S, H, KH, D = 2, 32, 8, 2, 16
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)

with mesh:
    sh = NamedSharding(mesh, P('data', 'model', None, None))
    qd, kd, vd = (jax.device_put(t, sh) for t in (q, k, v))
    got = jax.jit(lambda a, b, c: A.ulysses_attention(
        a, b, c, mesh, batch_spec=P('data'), causal=True, chunk=8))(qd, kd, vd)
want = A.flash_attention(q, k, v, causal=True, chunk=8)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=2e-5, rtol=2e-5)
print('MD_SP_OK')

# explicit-EP MoE (shard_map path) == pjit scatter path
import dataclasses
from repro.configs import get_config, smoke_config
from repro.models import moe, layers
cfg = dataclasses.replace(smoke_config(get_config('dbrx-132b')),
                          capacity_factor=8.0, num_shared_experts=0)
p = layers.init_from_plan(jax.random.PRNGKey(0), moe.moe_plan(cfg),
                          jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
y_ref, _ = moe.moe_apply(p, cfg, x)
with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
    ps = jax.device_put(p, NamedSharding(mesh, P()))
    ps['wi'] = jax.device_put(p['wi'], NamedSharding(mesh, P('model')))
    ps['wo'] = jax.device_put(p['wo'], NamedSharding(mesh, P('model')))
    y_ep, _ = jax.jit(lambda pp, xx: moe.moe_ep_explicit(
        pp, cfg, xx, mesh))(ps, xs)
# same expert math; dispatch pooling differs (per-device capacity pool) —
# with cf=8 nothing drops, so the results must match exactly
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           atol=1e-4, rtol=1e-4)
print('MD_EP_OK')
'''


@pytest.mark.slow
def test_multidevice_train_steps():
    r = subprocess.run([sys.executable, '-c', TRAIN_WORKER],
                       capture_output=True, text=True, timeout=1800)
    assert r.stdout.count('MD_TRAIN_OK') == 3, r.stdout + r.stderr[-3000:]


@pytest.mark.slow
def test_multidevice_sp_and_ep():
    r = subprocess.run([sys.executable, '-c', SP_WORKER],
                       capture_output=True, text=True, timeout=1800)
    assert 'MD_SP_OK' in r.stdout and 'MD_EP_OK' in r.stdout, \
        r.stdout + r.stderr[-3000:]
