"""Fuzzing the wire protocol decoder: arbitrary, mutated, and
truncated byte streams must resolve to a typed ProtocolError, a valid
frame, or a clean EOF (None) — never a hang, an unbounded allocation,
or an untyped exception.

Two layers: a seeded deterministic fuzz (always runs — the CI floor)
and a hypothesis property suite (skipped when hypothesis is not
installed, matching test_serve_properties.py)."""
import json
import random
import socket
import struct
import threading

import numpy as np
import pytest

from repro.serve import protocol as proto


def _valid_frames():
    """A spread of well-formed frames covering the meta/array space."""
    return [
        proto.pack_frame(proto.HELLO, {'tenant': 'fuzz', 'client_id': 'c'}),
        proto.pack_frame(proto.SUBMIT,
                         {'req_id': 1, 'direction': 'fwd', 'key': 'c/1'},
                         [np.arange(64, dtype=np.complex64)
                          .reshape(8, 8)]),
        proto.pack_frame(proto.RESULT, {'req_id': 2, 'form': 'planar'},
                         [np.ones((4, 4), np.float32),
                          np.zeros((4, 4), np.float32)]),
        proto.pack_frame(proto.HEARTBEAT, {}),
        proto.pack_frame(proto.RELOAD,
                         {'req_id': 3,
                          'tenants': [{'name': 't', 'weight': 2.0}]}),
        proto.pack_frame(proto.ERROR, {'kind': 'protocol', 'error': 'x'}),
    ]


def _check_unpack(buf: bytes) -> None:
    """The fuzz oracle: unpack either succeeds with sane structure or
    raises ProtocolError — anything else is a bug."""
    try:
        msg_type, meta, arrays, consumed = proto.unpack_frame(buf)
    except proto.ProtocolError:
        return
    assert isinstance(msg_type, int)
    assert isinstance(meta, dict)
    assert isinstance(arrays, list)
    assert 0 < consumed <= len(buf)
    for a in arrays:
        assert a.dtype.name in proto.WIRE_DTYPES


def _drain_socket(payload: bytes):
    """Feed ``payload`` through a real socketpair and collect what
    recv_frame makes of it: ('frames', [...]) on full drain,
    ('error', exc) on a typed rejection. The writer side closes after
    the payload, so a truncated tail is an EOF, never a hang."""
    a, b = socket.socketpair()
    try:
        def feed():
            try:
                a.sendall(payload)
            except OSError:
                pass
            finally:
                try:
                    a.shutdown(socket.SHUT_WR)
                except OSError:
                    pass

        t = threading.Thread(target=feed, daemon=True)
        t.start()
        frames = []
        try:
            while True:
                f = proto.recv_frame(b)
                if f is None:
                    break
                frames.append(f)
        except proto.ProtocolError as exc:
            return 'error', exc
        finally:
            t.join(timeout=10.0)
            assert not t.is_alive(), "feeder wedged"
        return 'frames', frames
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# Deterministic seeded fuzz — always runs
# ---------------------------------------------------------------------------

def test_unpack_random_garbage_never_escapes_protocolerror():
    rng = random.Random(0xF0F0)
    for _ in range(500):
        n = rng.randrange(0, 200)
        _check_unpack(bytes(rng.randrange(256) for _ in range(n)))


def test_unpack_mutated_valid_frames():
    """Single-byte corruption of every position in real frames: each
    mutant parses, or fails typed. (Bit flips in raw array payload
    bytes legitimately still parse — the protocol checksums structure,
    not content.)"""
    rng = random.Random(0xBEEF)
    for frame in _valid_frames():
        for pos in range(len(frame)):
            mutant = bytearray(frame)
            mutant[pos] ^= 1 << rng.randrange(8)
            _check_unpack(bytes(mutant))


def test_unpack_every_truncation_is_typed():
    for frame in _valid_frames():
        for cut in range(len(frame)):
            if cut == 0:
                continue
            with pytest.raises(proto.ProtocolError):
                proto.unpack_frame(frame[:cut])


def test_unpack_oversize_length_prefix_never_allocates():
    """A hostile header claiming a huge payload is refused from the
    8 header bytes alone — the decoder must not trust the length."""
    huge = proto._HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                              proto.SUBMIT, 0, proto.MAX_FRAME_BYTES + 1)
    with pytest.raises(proto.ProtocolError):
        proto.unpack_frame(huge + b'x' * 64)


def test_unpack_lying_array_descriptors():
    cases = [
        {'dtype': 'object', 'shape': [1], 'nbytes': 8},
        {'dtype': 'float32', 'shape': [-1], 'nbytes': 4},
        {'dtype': 'float32', 'shape': [2, 2], 'nbytes': 9999},
        {'dtype': 'float32', 'shape': 'nope', 'nbytes': 4},
        {'dtype': 'float32'},
    ]
    for desc in cases:
        jb = json.dumps({'req_id': 1, 'arrays': [desc]}).encode()
        payload = proto._JLEN.pack(len(jb)) + jb + b'\x00' * 16
        buf = proto._HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                                 proto.SUBMIT, 0, len(payload)) + payload
        with pytest.raises(proto.ProtocolError):
            proto.unpack_frame(buf)


def test_unpack_non_object_metadata_rejected():
    for meta_json in (b'[1,2]', b'"str"', b'42', b'null', b'\xff\xfe'):
        payload = proto._JLEN.pack(len(meta_json)) + meta_json
        buf = proto._HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                                 proto.HELLO, 0, len(payload)) + payload
        with pytest.raises(proto.ProtocolError):
            proto.unpack_frame(buf)


def test_recv_frame_clean_eof_vs_midframe_eof():
    frame = _valid_frames()[1]
    # whole frames then clean close -> all frames, then None
    status, frames = _drain_socket(frame * 3)
    assert status == 'frames' and len(frames) == 3
    # EOF inside the second frame -> first frame parses, then typed error
    status, err = _drain_socket(frame + frame[:len(frame) // 2])
    assert status == 'error'
    assert 'truncat' in str(err) or 'EOF' in str(err)
    # empty stream -> clean close immediately
    status, frames = _drain_socket(b'')
    assert status == 'frames' and frames == []


def test_recv_frame_random_garbage_streams():
    rng = random.Random(0xCAFE)
    for _ in range(50):
        n = rng.randrange(1, 300)
        blob = bytes(rng.randrange(256) for _ in range(n))
        status, _ = _drain_socket(blob)
        assert status in ('frames', 'error')


def test_recv_frame_hostile_length_does_not_allocate_or_hang():
    huge = proto._HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                              proto.SUBMIT, 0, proto.MAX_FRAME_BYTES - 1)
    status, err = _drain_socket(huge)      # header only, then EOF
    assert status == 'error'               # truncation, not a 1GB alloc


def test_round_trip_identity():
    rng = np.random.default_rng(7)
    metas = [{}, {'req_id': 0}, {'nested': {'a': [1, 2, {'b': None}]},
                                 'unicode': 'héllo→'}]
    arr_sets = [
        [],
        [rng.standard_normal((3, 5)).astype(np.float32)],
        [rng.standard_normal(8).astype(np.complex128),
         np.arange(6, dtype=np.int64).reshape(2, 3)],
        [np.float16(1.5) * np.ones((2, 2), np.float16)],
    ]
    for meta in metas:
        for arrs in arr_sets:
            buf = proto.pack_frame(proto.SUBMIT, meta, arrs)
            mt, m2, a2, consumed = proto.unpack_frame(buf)
            assert (mt, consumed) == (proto.SUBMIT, len(buf))
            assert m2 == meta
            assert len(a2) == len(arrs)
            for x, y in zip(arrs, a2):
                assert x.dtype == y.dtype and x.shape == y.shape
                assert np.array_equal(x, y)


def test_version_mismatch_is_its_own_type():
    frame = bytearray(_valid_frames()[0])
    frame[4] = proto.PROTOCOL_VERSION + 1      # the version byte
    with pytest.raises(proto.VersionMismatch):
        proto.unpack_frame(bytes(frame))


# ---------------------------------------------------------------------------
# Hypothesis property suite — optional dev dependency. Guarded with a
# conditional import (NOT importorskip) so the deterministic fuzz
# above always runs even without hypothesis installed.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.binary(max_size=512))
    def test_hyp_unpack_arbitrary_bytes(buf):
        _check_unpack(buf)

    @settings(max_examples=100, deadline=None)
    @given(st.data())
    def test_hyp_mutate_valid_frame(data):
        frames = _valid_frames()
        frame = bytearray(data.draw(st.sampled_from(frames)))
        for _ in range(data.draw(st.integers(1, 4))):
            pos = data.draw(st.integers(0, len(frame) - 1))
            frame[pos] = data.draw(st.integers(0, 255))
        _check_unpack(bytes(frame))

    @settings(max_examples=100, deadline=None)
    @given(st.data())
    def test_hyp_truncate_and_pad(data):
        frame = data.draw(st.sampled_from(_valid_frames()))
        cut = data.draw(st.integers(0, len(frame)))
        pad = data.draw(st.binary(max_size=32))
        _check_unpack(frame[:cut] + pad)

    @settings(max_examples=50, deadline=None)
    @given(meta=st.dictionaries(
        st.text(min_size=1, max_size=8).filter(lambda s: s != 'arrays'),
        st.one_of(st.none(), st.booleans(), st.integers(-2**31, 2**31),
                  st.floats(allow_nan=False, allow_infinity=False),
                  st.text(max_size=16)),
        max_size=6))
    def test_hyp_meta_round_trip(meta):
        buf = proto.pack_frame(proto.METRICS, meta)
        _, m2, arrays, consumed = proto.unpack_frame(buf)
        assert m2 == meta and arrays == [] and consumed == len(buf)
else:
    def test_hypothesis_property_suite():
        pytest.skip("hypothesis not installed — the deterministic "
                    "fuzz above is the CI floor")
