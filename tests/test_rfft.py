"""Real-input (rfft/irfft) plans: local correctness, the half-spectrum
cost model, the measured-cost autotune table, and facade validation.

Single-device tests run in-process on a 1x1 mesh; the 16-fake-device
matrix (ranks x strategies x methods x shardings x padded mode) runs in
a subprocess (see _rfft_worker.py)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.fft as fft
from repro.comm import cost as ccost
from repro.core import wse_model as wm
from repro.fft import methods, pencil

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RNG = np.random.default_rng(23)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


# ---------------------------------------------------------------------------
# Local r2c/c2r machinery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["stockham", "four_step", "block",
                                    "direct", "auto"])
def test_apply_real_matches_numpy(method):
    x = RNG.standard_normal((3, 64)).astype(np.float32)
    yr, yi = methods.apply_real(jnp.asarray(x), method=method)
    want = np.fft.rfft(x, axis=-1)
    got = np.asarray(yr, np.float64) + 1j * np.asarray(yi, np.float64)
    np.testing.assert_allclose(got, want, atol=3e-4 * np.max(np.abs(want)))
    # bins 0 and n/2 have exactly-zero imaginary parts by construction
    assert np.all(np.asarray(yi)[:, 0] == 0)
    assert np.all(np.asarray(yi)[:, -1] == 0)
    back = methods.apply_real(yr, yi, inverse=True, method=method)
    np.testing.assert_allclose(np.asarray(back), x, atol=1e-4)


def test_apply_real_axis_general():
    x = RNG.standard_normal((4, 16, 3)).astype(np.float32)
    yr, yi = methods.apply_real(jnp.asarray(x), axis=1)
    want = np.fft.rfft(x, axis=1)
    got = np.asarray(yr, np.float64) + 1j * np.asarray(yi, np.float64)
    np.testing.assert_allclose(got, want, atol=1e-4 * np.max(np.abs(want)))
    back = methods.apply_real(yr, yi, axis=1, inverse=True)
    np.testing.assert_allclose(np.asarray(back), x, atol=1e-4)


def test_apply_real_validation():
    x = jnp.zeros((4, 9))
    with pytest.raises(ValueError, match="even length"):
        methods.apply_real(x)
    with pytest.raises(ValueError, match="planar"):
        methods.apply_real(jnp.zeros((4, 5)), inverse=True)
    with pytest.raises(ValueError, match="ONE real array"):
        methods.apply_real(jnp.zeros((4, 8)), jnp.zeros((4, 8)))
    # every registered method carries a real_fn
    for name in methods.names():
        assert methods.get(name).real_fn is not None


# ---------------------------------------------------------------------------
# Facade round trips (1x1 mesh) + validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(256,), (16, 32), (8, 8, 8)])
@pytest.mark.parametrize("method", ["four_step", "stockham"])
def test_rplan_roundtrip(mesh, shape, method):
    x = RNG.standard_normal(shape).astype(np.float32)
    p = fft.rplan(shape, mesh, method=method)
    y = p.forward(jnp.asarray(x))
    rank = len(shape)
    want = np.fft.rfftn(x, axes=tuple(range(-rank, 0)))
    assert y.shape == p.spectrum_shape
    np.testing.assert_allclose(np.asarray(y, np.complex128), want,
                               atol=3e-4 * np.max(np.abs(want)))
    back = p.inverse(y)
    assert not np.iscomplexobj(np.asarray(back))
    np.testing.assert_allclose(np.asarray(back), x, atol=1e-4)
    nb = np.fft.irfftn(want, s=shape, axes=tuple(range(-rank, 0)))
    np.testing.assert_allclose(np.asarray(back, np.float64), nb, atol=1e-4)


def test_rplan_validation(mesh):
    with pytest.raises(ValueError, match="even last axis"):
        fft.rplan((8, 9), mesh)
    with pytest.raises(ValueError, match="padded_spectrum"):
        fft.plan((8, 8), mesh, padded_spectrum=True)
    with pytest.raises(ValueError, match="padded_spectrum"):
        fft.rplan((256,), mesh, padded_spectrum=True)
    p = fft.rplan((8, 8), mesh)
    with pytest.raises(ValueError, match="REAL array"):
        p.forward(jnp.zeros((8, 8), jnp.complex64))
    with pytest.raises(ValueError, match="ONE real array"):
        p.forward((jnp.zeros((8, 8)), jnp.zeros((8, 8))))
    with pytest.raises(ValueError, match="does not end with"):
        p.inverse(jnp.zeros((8, 8), jnp.complex64))   # spectrum is (8, 5)
    with pytest.raises(ValueError, match="must start in memory"):
        fft.rplan((8, 8, 8), mesh, layout=('x', None, 'y'))


def test_apply_accepts_plain_lists(mesh):
    """Planar operands given as plain Python lists must be coerced, not
    crash on `.shape` (regression: only np.ndarray was converted)."""
    p = fft.plan((4,), mesh)
    re = [1.0, 2.0, 3.0, 4.0]
    im = [0.0, 0.0, 0.0, 0.0]
    yr, yi = p.forward((re, im))
    want = np.fft.fft(np.asarray(re))
    got = np.asarray(yr, np.float64) + 1j * np.asarray(yi, np.float64)
    np.testing.assert_allclose(got, want, atol=1e-5)
    # nested lists too (rank 2)
    p2 = fft.plan((2, 2), mesh)
    y2r, y2i = p2.forward(([[1.0, 2.0], [3.0, 4.0]],
                           [[0.0, 0.0], [0.0, 0.0]]))
    np.testing.assert_allclose(
        np.asarray(y2r) + 1j * np.asarray(y2i),
        np.fft.fftn([[1.0, 2.0], [3.0, 4.0]]), atol=1e-5)


# ---------------------------------------------------------------------------
# Half-spectrum schedule bookkeeping + cost model
# ---------------------------------------------------------------------------

def test_real_padded_extent():
    assert pencil.real_half_extent(16) == 9
    assert pencil.real_padded_extent((16, 16, 16), ('x', 'y', None),
                                     {'x': 4, 'y': 4}) == 12
    assert pencil.real_padded_extent((512,) * 3, ('x', 'y', None),
                                     {'x': 4, 'y': 4}) == 260
    assert pencil.real_padded_extent((32, 64), (('x', 'y'), None),
                                     {'x': 4, 'y': 4}) == 48
    # 1x1 mesh: no sharding, the odd extent rides as-is
    assert pencil.real_padded_extent((8, 8, 8), ('x', 'y', None),
                                     {'x': 1, 'y': 1}) == 5


def test_real_schedule_transforms_last_axis_first():
    steps, final = pencil.forward_schedule(('x', 'y', None), 2)
    assert steps[0] == ('fft', 2)
    with pytest.raises(ValueError, match="must start in memory"):
        pencil.forward_schedule(('x', None, 'y'), 2)


def test_real_plan_cost_halves_wire():
    """ACCEPTANCE: a real 3-D plan's wire cycles < 0.55x the matching
    complex plan (analytic model) at multi-pencil granularity."""
    for n, mesh_shape in ((512, {'x': 4, 'y': 4}), (512, {'x': 8, 'y': 8}),
                          (512, {'x': 16, 'y': 16}),
                          (1024, {'x': 32, 'y': 32})):
        cc = ccost.pencil_plan_cost((n,) * 3, ('x', 'y', None), mesh_shape,
                                    measured=None)
        cr = ccost.pencil_plan_cost((n,) * 3, ('x', 'y', None), mesh_shape,
                                    real=True, measured=None)
        ratio = cr.wire_cycles / cc.wire_cycles
        assert ratio < 0.55, (mesh_shape, ratio)
        # compute halves too: r2c superstep + halved later supersteps
        fftc = sum(s.cycles for s in cc.steps if s.kind in ('fft', 'rfft'))
        fftr = sum(s.cycles for s in cr.steps if s.kind in ('fft', 'rfft'))
        assert fftr < 0.62 * fftc, (mesh_shape, fftr / fftc)
    kinds = [s.kind for s in cr.steps]
    assert kinds == ['rfft', 'swap', 'fft', 'swap', 'fft']


def test_real_plan_cost_m1_degenerates_gracefully():
    """At the paper's single-pencil granularity (mesh extent = n) the
    truncated axis pads back to full extent — the cost model must price
    that honestly: no wire win, never a loss."""
    cc = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 512, 'y': 512}, measured=None)
    cr = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 512, 'y': 512}, real=True,
                                measured=None)
    assert cr.wire_cycles == pytest.approx(cc.wire_cycles)
    assert pencil.real_padded_extent((512,) * 3, ('x', 'y', None),
                                     {'x': 512, 'y': 512}) == 512


def test_real_plan_cost_np_layout_gather_is_priced():
    cr = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 8, 'y': 8}, real=True,
                                padded_spectrum=False, measured=None)
    assert [s.kind for s in cr.steps][-1] == 'gather'
    cc = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 8, 'y': 8}, measured=None)
    # even with the boundary gather the wire stays well under the
    # complex plan
    assert cr.wire_cycles < 0.85 * cc.wire_cycles


def test_rplan_facade_cost_on_abstract_mesh():
    from jax import sharding
    if not hasattr(sharding, 'AbstractMesh'):
        pytest.skip("jax.sharding.AbstractMesh unavailable")
    amesh = sharding.AbstractMesh((('x', 16), ('y', 16)))
    pr = fft.rplan((512,) * 3, amesh, comm='all_to_all',
                   padded_spectrum=True)
    pc = fft.plan((512,) * 3, amesh, comm='all_to_all')
    ratio = (pr.plan_cost(measured=None).wire_cycles
             / pc.plan_cost(measured=None).wire_cycles)
    assert ratio < 0.55, ratio
    assert 'rfft' in pr.cost_report()


def test_rfft_pencil_cycle_model():
    # rfft pencil ~ half the complex pencil, plus the O(n) combine
    for n in (64, 512, 4096):
        full = wm.pencil_cycles_method(n, 'fp32', 'stockham')
        half = wm.rfft_pencil_cycles_method(n, 'fp32', 'stockham')
        assert half < 0.75 * full
        assert half > wm.pencil_cycles_method(n // 2, 'fp32', 'stockham')


# ---------------------------------------------------------------------------
# r2c overlap (split-combine pair)
# ---------------------------------------------------------------------------

def test_rplan_overlap_bit_equivalence(mesh):
    """ACCEPTANCE: overlapped vs unoverlapped rplan execution is
    bit-identical with overlap_chunks > 1 — the r2c superstep now rides
    inside an overlap pair via the split-combine formulation."""
    shape = (16, 16, 16)
    x = RNG.standard_normal(shape).astype(np.float32)
    base = fft.rplan(shape, mesh, overlap_chunks=1)
    want = np.asarray(base.forward(jnp.asarray(x)))
    for oc in (2, 4):
        p = fft.rplan(shape, mesh, overlap_chunks=oc)
        got = np.asarray(p.forward(jnp.asarray(x)))
        assert np.array_equal(want, got), oc
        back = np.asarray(p.inverse(jnp.asarray(got)))
        assert np.array_equal(
            np.asarray(base.inverse(jnp.asarray(want))), back), oc


def test_r2c_step_is_overlappable_in_cost_model():
    """ACCEPTANCE: cost_report no longer lists the r2c step as
    unoverlappable — the (rfft, swap) pair is priced and marked as an
    overlap pair like any (fft, swap) pair."""
    pc = ccost.pencil_plan_cost((512,) * 3, ('x', 'y', None),
                                {'x': 8, 'y': 8}, real=True,
                                overlap_chunks=4, measured=None)
    assert pc.steps[0].kind == 'rfft' and pc.steps[1].kind == 'swap'
    assert 0 in pc.overlapped_steps() and 1 in pc.overlapped_steps()
    # pipelining the pair makes the r2c total cheaper than serial
    assert pc.cycles < pc.serial_cycles
    rep = ccost.format_report(pc, (512,) * 3, {'x': 8, 'y': 8})
    rfft_line = next(ln for ln in rep.splitlines() if ' rfft ' in ln)
    assert '~ovl' in rfft_line, rfft_line


def test_feasible_overlap_includes_r2c_pair():
    # (24, 24, 24) on 4x4: the r2c pair chunks the free y axis (local
    # 6) and the second pair chunks the padded half axis (16/4 = 4), so
    # depth 2 is feasible for the WHOLE real plan — before the
    # split-combine formulation the r2c pair disqualified everything
    ok = ccost.feasible_overlap((24, 24, 24), ('x', 'y', None),
                                {'x': 4, 'y': 4}, real=True)
    assert 2 in ok
    # (16, 16, 16) on 4x4: the r2c pair could chunk (free local 4), but
    # the second pair's only free axis is the padded half axis at local
    # extent 3 — the every-pair rule honestly reports serial-only (the
    # executor then falls back per pair, bit-exactly)
    ok3 = ccost.feasible_overlap((16, 16, 16), ('x', 'y', None),
                                 {'x': 4, 'y': 4}, real=True)
    assert ok3 == (1,)
    # rank-2 real: the r2c pair has NO free axis (both array axes are
    # the fft axis or the swap's shard axis) -> only the serial depth
    ok2 = ccost.feasible_overlap((32, 64), (('x', 'y'), None),
                                 {'x': 4, 'y': 4}, real=True)
    assert ok2 == (1,)


# ---------------------------------------------------------------------------
# Measured-cost autotune table
# ---------------------------------------------------------------------------

def _table(rows):
    return ccost.MeasuredTable(rows)


def _row(strategy, us, elems, mesh="4x4", group="x"):
    return dict(mesh=mesh, group=group, strategy=strategy, p=4,
                local_elems=elems, us=us)


def test_measured_table_interpolation():
    t = _table([_row('all_to_all', 100.0, 1024),
                _row('all_to_all', 400.0, 16384)])
    # exact endpoints
    assert t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 1024) == 100.0
    assert t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 16384) == 400.0
    # log-space interpolation between samples: geometric midpoint
    mid = t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 4096)
    assert 100.0 < mid < 400.0
    assert mid == pytest.approx(200.0, rel=1e-6)
    # outside the measured range (beyond 2x margin): fall back to model
    assert t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 1 << 22) is None
    assert t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 8) is None
    # unmeasured mesh / group / strategy: no entry
    assert t.swap_us('all_to_all', {'x': 512, 'y': 512}, 'x', 2048) is None
    assert t.swap_us('ppermute', {'x': 4, 'y': 4}, 'x', 2048) is None


def test_select_prefers_measured_over_model():
    """The selector must follow the measurements when they cover the
    config — here a table claiming ppermute is 100x faster flips the
    choice away from the analytic winner."""
    rows = []
    for g in ('x', 'y'):
        rows += [_row('all_to_all', 10000.0, 256, group=g),
                 _row('all_to_all', 10000.0, 4096, group=g),
                 _row('ppermute', 100.0, 256, group=g),
                 _row('ppermute', 100.0, 4096, group=g),
                 _row('hierarchical', 10000.0, 256, group=g),
                 _row('hierarchical', 10000.0, 4096, group=g)]
    t = _table(rows)
    sel = ccost.select((16, 16, 16), ('x', 'y', None), {'x': 4, 'y': 4},
                       measured=t)
    assert sel.strategy == 'ppermute'
    # the same config under the pure analytic model picks all_to_all
    sel_a = ccost.select((16, 16, 16), ('x', 'y', None), {'x': 4, 'y': 4},
                         measured=None)
    assert sel_a.strategy == 'all_to_all'
    # measured steps are labelled in the report
    pc = sel.cost
    assert any('measured' in s.detail for s in pc.steps if s.kind == 'swap')


def test_measured_table_dtype_keying():
    """Rows carrying a dtype tag form separate grids; dtype-less
    (legacy) rows — which timed f32 arrays — answer 'c64' queries only
    (serving them to a c128 query would halve the priced wire time)."""
    rows = [dict(_row('all_to_all', 100.0, 1024), dtype='c64'),
            dict(_row('all_to_all', 300.0, 1024), dtype='c128'),
            _row('ppermute', 50.0, 1024)]          # legacy, no dtype
    t = _table(rows)
    ms = {'x': 4, 'y': 4}
    assert t.swap_us('all_to_all', ms, 'x', 1024) == 100.0           # c64
    assert t.swap_us('all_to_all', ms, 'x', 1024, dtype='c128') == 300.0
    # unmeasured dtype -> None (fall back to the analytic model)
    assert t.swap_us('all_to_all', ms, 'x', 1024, dtype='c256') is None
    # legacy rows answer c64 but NOT other dtypes
    assert t.swap_us('ppermute', ms, 'x', 1024) == 50.0
    assert t.swap_us('ppermute', ms, 'x', 1024, dtype='c128') is None


def test_measured_table_loader(tmp_path, monkeypatch):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(dict(results=[
        _row('all_to_all', 123.0, 2048)])))
    t = ccost.measured_table(str(path))
    assert t is not None and len(t) == 1
    assert t.swap_us('all_to_all', {'x': 4, 'y': 4}, 'x', 2048) == 123.0
    # env var '' disables the default table entirely
    monkeypatch.setenv(ccost.MEASURED_ENV, '')
    assert ccost.measured_table() is None
    monkeypatch.setenv(ccost.MEASURED_ENV, str(path))
    assert ccost.measured_table() is not None
    # junk file -> None, not an exception
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert ccost.measured_table(str(bad)) is None
    # the repo-root BENCH_redistribute.json loads by default
    monkeypatch.delenv(ccost.MEASURED_ENV, raising=False)
    tbl = ccost.measured_table()
    if os.path.exists(os.path.join(ROOT, 'BENCH_redistribute.json')):
        assert tbl is not None and len(tbl) > 0


# ---------------------------------------------------------------------------
# 16-device matrix (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_rfft_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_rfft_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stdout[-4000:] + "\n" + proc.stderr[-4000:]
    assert "RFFT_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 40
