"""Serving invariants: prefill + decode must reproduce the full
forward pass (the correctness contract of every cache kind)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, make_batch, smoke_config
from repro.models import model as M

CAUSAL_TOKEN_ARCHS = [a for a in sorted(ARCHS)
                      if ARCHS[a].causal and ARCHS[a].input_mode == 'tokens']


def _no_drop(cfg):
    if cfg.moe:
        return dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


@pytest.mark.parametrize('arch', CAUSAL_TOKEN_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _no_drop(smoke_config(get_config(arch)))
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    S, extra, cap = 24, 4, 32
    batch = make_batch(cfg, batch=2, seq=S + extra, dtype=jnp.float32)
    full_b = {k: v for k, v in batch.items() if k != 'labels'}
    logits_full, _ = M.forward(params, cfg, full_b)
    pre_b = {k: (v[:, :S] if k != 'positions' else v[..., :S])
             for k, v in full_b.items()}
    logits_pre, caches = M.prefill(params, cfg, pre_b, cache_cap=cap)
    np.testing.assert_allclose(logits_pre[:, 0], logits_full[:, S - 1],
                               atol=2e-3, rtol=2e-3)
    for t in range(extra):                       # decode the continuation
        tok = full_b['tokens'][:, S + t:S + t + 1]
        logits_dec, caches = M.decode_step(params, cfg, caches, tok,
                                           jnp.int32(S + t))
        np.testing.assert_allclose(
            logits_dec[:, 0], logits_full[:, S + t], atol=3e-3, rtol=3e-3)


def test_ring_cache_beyond_window():
    """Sliding-window ring buffer: decode far past the window length and
    compare against the full forward with the same window mask."""
    cfg = _no_drop(smoke_config(get_config('recurrentgemma-9b')))
    assert cfg.window == 16
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    S_total = 48                                  # 3x the window
    batch = make_batch(cfg, batch=2, seq=S_total, dtype=jnp.float32)
    full_b = {'tokens': batch['tokens']}
    logits_full, _ = M.forward(params, cfg, full_b)
    S0 = 8                                        # prefill shorter than W
    _, caches = M.prefill(params, cfg, {'tokens': batch['tokens'][:, :S0]},
                          cache_cap=S_total)
    for t in range(S0, S_total):
        tok = batch['tokens'][:, t:t + 1]
        logits_dec, caches = M.decode_step(params, cfg, caches, tok,
                                           jnp.int32(t))
        np.testing.assert_allclose(logits_dec[:, 0], logits_full[:, t],
                                   atol=3e-3, rtol=3e-3,
                                   err_msg=f'position {t}')


def test_mla_cache_is_compressed():
    """The MLA decode cache stores kv_lora + rope dims per token — not
    2 * heads * head_dim (the memory claim of the architecture)."""
    cfg = smoke_config(get_config('deepseek-v2-236b'))
    plan = M.cache_plan(cfg, B=2, cap=32)
    import jax.tree_util as jtu
    from repro.models.layers import is_pspec
    leaves = jax.tree.leaves(plan, is_leaf=is_pspec)
    per_token = sum(np.prod(p.shape) / (2 * 32) for p in leaves
                    if len(p.shape) == 3 and p.shape[1] == 32)
    full_kv = cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
    assert per_token <= (cfg.kv_lora_rank + cfg.rope_head_dim) + 1
    assert per_token < full_kv / 8
