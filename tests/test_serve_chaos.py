"""Chaos gate for the resilient serving stack: the seeded
fault-injection plan against the full service on a 16-fake-device
mesh, in a subprocess (tests/_service_chaos_worker.py).

The worker asserts the acceptance contract end to end: no hang, no
lost or duplicated result, bit-identity under connection drops /
truncated frames / dispatch faults, the >= 40% fairness floor for an
equal-weight tenant under a flood, idempotent-resubmit re-delivery,
brownout shed + recovery, and hot config reload — plus the metrics
surface (scheduler shares, dedup hit/miss, breaker transitions,
reload generation) those mechanisms expose."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.mark.slow
def test_service_chaos_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_SERVE_SCHEDULES"] = ""        # deterministic picks
    proc = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_service_chaos_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stdout[-4000:] + "\n" + proc.stderr[-4000:]
    assert "SERVICE_CHAOS_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 5
