"""Continuous serving: the background drainer, the multi-shape LRU
plan cache, and the donated-operand retry snapshots.

In-process tests run on a 1x1 mesh (fast paths: deadline/watermark
triggers, close semantics, failure re-queue + retry, LRU eviction).
The 16-fake-device concurrency matrix — N producer threads x mixed
shapes/kinds/directions, deadline-only and watermark-only loads,
bit-identity to per-request execution, drainer exception injection —
runs in a subprocess (tests/_serve_drainer_worker.py)."""
import os
import subprocess
import sys
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.comm import overlap as ov
from repro.serve import FFTEngine, LRUPlanCache

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RNG = np.random.default_rng(37)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


def _creq(shape):
    return (RNG.standard_normal(shape)
            + 1j * RNG.standard_normal(shape)).astype(np.complex64)


# ---------------------------------------------------------------------------
# Background drainer: triggers, close, context manager
# ---------------------------------------------------------------------------

def test_deadline_serves_without_flush(mesh):
    with FFTEngine((8, 8), mesh, max_wait_ms=5.0, watermark=10**6,
                   schedule_table=None) as eng:
        x = _creq((8, 8))
        t = eng.submit(x)
        got = t.result(timeout=60)            # no flush() anywhere
        np.testing.assert_allclose(np.asarray(got), np.fft.fftn(x),
                                   atol=1e-3)
        assert t.done


def test_watermark_serves_without_flush(mesh):
    # no deadline at all: dispatch happens only when a kind's queue
    # reaches the watermark (or at close)
    with FFTEngine((8, 8), mesh, watermark=2, schedule_table=None) as eng:
        xs = [_creq((8, 8)) for _ in range(2)]
        t0 = eng.submit(xs[0])
        time.sleep(0.05)
        assert not t0.done                    # below watermark: queued
        t1 = eng.submit(xs[1])                # trips the watermark
        for t, x in zip((t0, t1), xs):
            np.testing.assert_allclose(np.asarray(t.result(timeout=60)),
                                       np.fft.fftn(x), atol=1e-3)


def test_close_drains_and_submit_after_close_raises(mesh):
    eng = FFTEngine((8, 8), mesh, watermark=10**6, schedule_table=None)
    xs = [_creq((8, 8)) for _ in range(3)]
    tickets = [eng.submit(x) for x in xs]
    eng.close()                               # final pass drains the queue
    for t, x in zip(tickets, xs):
        np.testing.assert_allclose(np.asarray(t.result(timeout=60)),
                                   np.fft.fftn(x), atol=1e-3)
    with pytest.raises(RuntimeError, match="close"):
        eng.submit(xs[0])
    eng.close()                               # idempotent
    assert eng.closed


def test_foreground_close_flushes(mesh):
    eng = FFTEngine((8, 8), mesh, schedule_table=None)
    x = _creq((8, 8))
    t = eng.submit(x)
    eng.close()
    assert t.done
    with pytest.raises(RuntimeError, match="close"):
        eng.submit(x)


def test_mixed_shapes_and_kinds_no_flush(mesh):
    """One background engine serves >= 3 distinct shapes, complex and
    real, forward and inverse, with no explicit flush()."""
    shapes = [(8, 8), (4, 4), (8, 8, 8)]
    with FFTEngine(mesh=mesh, max_wait_ms=5.0, schedule_table=None) as eng:
        tickets, want = [], []
        for shape in shapes:
            xc = _creq(shape)
            xr = RNG.standard_normal(shape).astype(np.float32)
            tickets.append(eng.submit(xc))
            want.append(np.fft.fftn(xc))
            tickets.append(eng.submit(xr))
            want.append(np.fft.rfftn(xr))
        for t, w in zip(tickets, want):
            got = np.asarray(t.result(timeout=120))
            np.testing.assert_allclose(got, w,
                                       atol=3e-4 * np.max(np.abs(w)))
        # inverse serving: round-trip one of each kind through result()
        spec = tickets[0].result()
        back = eng.submit(spec, direction='inv').result(timeout=120)
        np.testing.assert_allclose(np.asarray(back),
                                   np.fft.ifftn(np.asarray(spec)),
                                   atol=1e-4)
        rspec = tickets[1].result()
        rback = eng.submit(rspec, direction='inv').result(timeout=120)
        assert not np.iscomplexobj(np.asarray(rback))
        assert np.asarray(rback).shape == shapes[0]


def test_engine_without_default_shape_requires_operands(mesh):
    eng = FFTEngine(mesh=mesh, schedule_table=None)
    with pytest.raises(ValueError, match="no default shape"):
        eng.schedule()
    x = _creq((4, 4))
    got = eng.transform([x])[0]
    np.testing.assert_allclose(np.asarray(got), np.fft.fftn(x), atol=1e-3)
    assert eng.serving_shapes() == [((4, 4), False)]


def test_transform_below_watermark_makes_progress(mesh):
    """A synchronous transform() must never depend on the drainer's
    triggers: one request below the watermark of a deadline-less
    engine would otherwise hang forever."""
    with FFTEngine((8, 8), mesh, watermark=8, schedule_table=None) as eng:
        x = _creq((8, 8))
        got = eng.transform([x], timeout=60)[0]
        np.testing.assert_allclose(np.asarray(got), np.fft.fftn(x),
                                   atol=1e-3)


def test_dropped_engine_is_reclaimed(mesh):
    """An engine dropped WITHOUT close() must not pin its drainer
    thread (and the whole plan cache) forever: the drainer holds the
    engine only via a weakref between passes, so the cyclic GC can
    collect it and the orphaned thread exits."""
    import gc
    import threading
    import weakref

    before = threading.active_count()
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, schedule_table=None)
    t = eng.submit(_creq((8, 8)))
    t.result(timeout=60)
    ref = weakref.ref(eng)
    del eng, t
    deadline = time.time() + 30
    while time.time() < deadline and (ref() is not None
                                      or threading.active_count() > before):
        gc.collect()
        time.sleep(0.2)
    assert ref() is None
    assert threading.active_count() == before


# ---------------------------------------------------------------------------
# Drainer failure handling: re-queue, retry, surface on result()
# ---------------------------------------------------------------------------

def test_drainer_failure_requeues_then_retry_succeeds(mesh, monkeypatch):
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, retries=3,
                    schedule_table=None)
    real_run = eng._run_group
    fails = {'left': 2}

    def flaky(*a, **k):
        if fails['left'] > 0:
            fails['left'] -= 1
            raise RuntimeError("injected drainer fault")
        return real_run(*a, **k)

    monkeypatch.setattr(eng, '_run_group', flaky)
    with eng:
        x = _creq((8, 8))
        got = eng.submit(x).result(timeout=60)   # retried, never dropped
        np.testing.assert_allclose(np.asarray(got), np.fft.fftn(x),
                                   atol=1e-3)
    assert fails['left'] == 0


def test_drainer_persistent_failure_surfaces_on_result(mesh, monkeypatch):
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, retries=1,
                    schedule_table=None)

    def boom(*a, **k):
        raise RuntimeError("persistent drainer fault")

    monkeypatch.setattr(eng, '_run_group', boom)
    with eng:
        t = eng.submit(_creq((8, 8)))
        with pytest.raises(RuntimeError, match="persistent drainer fault"):
            t.result(timeout=60)
    assert not t.done                          # failed, not silently None


def test_bystander_groups_survive_culprit_failure(mesh, monkeypatch):
    """A pipeline failure tears down every in-flight group, but only
    the CULPRIT group's requests burn retries: a persistently failing
    kind must not poison healthy traffic dispatched alongside it."""
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, retries=1,
                    schedule_table=None)
    real_run = eng._run_group

    def selective(plan, direction, planar, ops, *a, **k):
        if plan.real:
            raise RuntimeError("culprit kind")
        return real_run(plan, direction, planar, ops, *a, **k)

    monkeypatch.setattr(eng, '_run_group', selective)
    with eng:
        xc = _creq((8, 8))
        tc = eng.submit(xc)
        tr = eng.submit(RNG.standard_normal((8, 8)).astype(np.float32))
        with pytest.raises(RuntimeError, match="culprit kind"):
            tr.result(timeout=60)
        got = np.asarray(tc.result(timeout=60))   # healthy kind survives
        np.testing.assert_allclose(got, np.fft.fftn(xc), atol=1e-3)


def test_result_timeout(mesh):
    with FFTEngine((8, 8), mesh, watermark=10**6,
                   schedule_table=None) as eng:
        t = eng.submit(_creq((8, 8)))          # never ripe before close
        with pytest.raises(TimeoutError):
            t.result(timeout=0.05)
    assert t.done                              # close() drained it


# ---------------------------------------------------------------------------
# Donated-operand snapshots: a failed group's requests stay runnable
# ---------------------------------------------------------------------------

def test_failed_group_donated_operand_retries_cleanly(mesh, monkeypatch):
    """Regression (PR-4 UX): a donated operand consumed by a failed
    group used to leave the ticket poisoned — the re-queued request
    held a deleted buffer, so no retry could succeed. The engine now
    snapshots donated operands while their group is in flight and
    re-queues the snapshot."""
    eng = FFTEngine((8, 8), mesh, schedule_table=None)
    p = eng.plan_for(False)
    assert p.donates_input
    x_host = _creq((8, 8))
    x = jnp.asarray(x_host)
    t = eng.submit(x)
    real_run = eng._run_group

    def run_then_fail(*a, **k):
        real_run(*a, **k)                      # CONSUMES the donated input
        raise RuntimeError("post-dispatch fault")

    monkeypatch.setattr(eng, '_run_group', run_then_fail)
    with pytest.raises(RuntimeError, match="post-dispatch fault"):
        eng.flush()
    assert x.is_deleted()                      # the group really donated
    assert not t.done
    monkeypatch.undo()
    got = np.asarray(t.result())               # retry runs on the snapshot
    np.testing.assert_allclose(got, np.fft.fftn(x_host), atol=1e-3)


def test_snapshot_dropped_on_success(mesh):
    eng = FFTEngine((8, 8), mesh, schedule_table=None)
    x = jnp.asarray(_creq((8, 8)))
    t = eng.submit(x)
    eng.flush()
    assert t.done and x.is_deleted()           # donation contract intact


# ---------------------------------------------------------------------------
# Multi-shape LRU plan cache
# ---------------------------------------------------------------------------

def test_plan_lru_eviction_order_and_recompile_once(mesh):
    evicted = []
    eng = FFTEngine(mesh=mesh, max_plans=2, schedule_table=None,
                    on_plan_evict=lambda key, plan: evicted.append(key))
    for shape in ((8, 8), (4, 4), (16, 16)):
        eng.transform([_creq(shape)])
    # LRU evicted the first-served shape, kept the two most recent
    assert evicted == [((8, 8), False)]
    assert eng.serving_shapes() == [((4, 4), False), ((16, 16), False)]
    assert eng.plan_builds[((8, 8), False)] == 1
    # re-request the evicted shape: recompiles exactly once...
    eng.transform([_creq((8, 8))])
    eng.transform([_creq((8, 8))])
    assert eng.plan_builds[((8, 8), False)] == 2
    # ...and the eviction hook saw the next LRU victim go
    assert evicted == [((8, 8), False), ((4, 4), False)]


def test_plan_cache_byte_budget_evicts(mesh):
    eng = FFTEngine(mesh=mesh, plan_cache_bytes=1, schedule_table=None)
    eng.transform([_creq((8, 8))])
    assert len(eng._states) == 1               # sole entry may bust budget
    eng.transform([_creq((4, 4))])
    assert len(eng._states) == 1               # old shape evicted
    assert eng.serving_shapes() == [((4, 4), False)]


def test_inverse_inference_never_evicts_served_plans(mesh):
    """Regression: inferring an inverse's kind used to build (and
    LRU-insert) the default shape's real plan as a side effect, which
    could evict the very served plan the inference was about to match.
    Inference is now side-effect free."""
    eng = FFTEngine((8, 8), mesh, max_plans=2, schedule_table=None)
    y44 = eng.transform([_creq((4, 4))])[0]
    y44_host = np.asarray(y44)      # the donating inverse consumes y44
    eng.transform([_creq((8, 8))])
    cached = eng.serving_shapes()
    # the (4,4) inverse resolves against the served complex plan, and
    # the cache is untouched by the inference itself
    back = eng.transform([y44], direction='inv')[0]
    np.testing.assert_allclose(np.asarray(back),
                               np.fft.ifftn(y44_host), atol=1e-4)
    assert set(eng.serving_shapes()) == set(cached)
    # the default shape's np-layout real spectrum still infers real
    # without a real plan ever having been served
    spec = np.zeros((8, 5), np.complex64)
    t = eng.submit(spec, direction='inv')
    assert np.asarray(t.result()).shape == (8, 8)


def test_autotune_persist_disabled_raises(mesh):
    eng = FFTEngine((8, 8), mesh, max_coalesce=2, schedule_table=None)
    with pytest.raises(ValueError, match="persist"):
        eng.autotune([_creq((8, 8))], repeats=1, widths=(1,), chunks=(1,),
                     persist=True)


def test_set_schedule_resets_entry_bytes(mesh):
    """Regression: clearing a plan's group executables on reschedule
    must release their accounted bytes, or every autotune/set_schedule
    inflates the entry and evicts innocent siblings."""
    eng = FFTEngine((8, 8), mesh, schedule_table=None)
    eng.transform([_creq((8, 8))])
    key = ((8, 8), False)
    before = eng._states.nbytes(key)
    assert before > 0
    w, c = eng.schedule(False)
    eng.set_schedule(max(w, 2), 2)             # clears the executables
    assert eng._states.nbytes(key) == 0
    eng.transform([_creq((8, 8))])             # re-grows from zero
    assert 0 < eng._states.nbytes(key) <= 2 * before


def test_lru_plan_cache_unit():
    evicted = []
    c = LRUPlanCache(max_entries=2, on_evict=lambda k, v: evicted.append(k))
    c.put('a', 1)
    c.put('b', 2)
    assert c.get('a') == 1                     # 'a' now MRU
    c.put('c', 3)
    assert evicted == ['b'] and c.keys() == ['a', 'c']
    assert c.get('b') is None
    # byte budget with growth
    cb = LRUPlanCache(max_bytes=100)
    cb.put('x', 'X', nbytes=60)
    cb.put('y', 'Y', nbytes=30)
    cb.grow('y', 40)                           # 60 + 70 > 100 -> evict x
    assert cb.keys() == ['y'] and cb.total_bytes == 70
    cb.grow('y', 1000)                         # sole entry never evicted
    assert cb.keys() == ['y']
    with pytest.raises(ValueError, match="max_entries"):
        LRUPlanCache(max_entries=0)


# ---------------------------------------------------------------------------
# StreamPipeline (the drainer's persistent bounded window)
# ---------------------------------------------------------------------------

def test_stream_pipeline_push_drain_abort():
    forced = []
    pipe = ov.StreamPipeline(depth=2)
    for i in range(3):
        pipe.push(lambda i=i: jnp.asarray(float(i)),
                  lambda r, i=i: forced.append((i, float(r))))
    assert len(pipe) == 2                      # one was forced by the bound
    assert forced == [(0, 0.0)]
    pipe.drain()
    assert forced == [(0, 0.0), (1, 1.0), (2, 2.0)] and len(pipe) == 0
    pipe.push(lambda: jnp.asarray(9.0), lambda r: forced.append('no'))
    assert pipe.abort() == 1 and len(pipe) == 0
    assert forced[-1] != 'no'                  # aborted callbacks never run
    with pytest.raises(ValueError, match="depth"):
        ov.StreamPipeline(depth=0)


# ---------------------------------------------------------------------------
# 16-device concurrency matrix (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_drainer_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_SERVE_SCHEDULES"] = ""          # deterministic picks
    proc = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_serve_drainer_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stdout[-4000:] + "\n" + proc.stderr[-4000:]
    assert "SERVE_DRAINER_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 4
