"""The fault-injection plane and the service's resilience primitives,
tested without a mesh: determinism and scheduling of FaultPlan,
clock-skew hardening of the estimators and token buckets, the dedup
window's exactly-once bookkeeping, the brownout breaker's state
machine, weighted deficit round-robin, and hot-reloadable
TenantConfig round-trips. End-to-end validation over a real 16-device
service lives in tests/_service_chaos_worker.py."""
import math
import socket
import threading
import time

import pytest

from repro.serve.faults import (ACTIONS, FaultInjected, FaultPlan,
                                FaultPoint, kill_socket)
from repro.serve.policy import AdaptivePolicy, RateEstimator
from repro.serve.service import (BrownoutBreaker, TenantConfig,
                                 _DedupWindow, _FairScheduler,
                                 _TokenBucket)


# ---------------------------------------------------------------------------
# FaultPoint schedules
# ---------------------------------------------------------------------------

def test_fault_point_needs_exactly_one_schedule():
    with pytest.raises(ValueError):
        FaultPoint('s', 'drop')                      # no schedule
    with pytest.raises(ValueError):
        FaultPoint('s', 'drop', p=0.5, at=[1])       # two schedules
    with pytest.raises(ValueError):
        FaultPoint('s', 'nonsense', p=0.5)           # unknown action
    with pytest.raises(ValueError):
        FaultPoint('s', 'drop', every=0)
    with pytest.raises(ValueError):
        FaultPoint('s', 'drop', p=1.5)
    for a in ACTIONS:
        FaultPoint('s', a, p=0.5)                    # all actions arm


def test_scripted_at_schedule_fires_exactly_there():
    plan = FaultPlan([FaultPoint('x', 'raise', at=[0, 3])])
    fired = [plan.draw('x') is not None for _ in range(6)]
    assert fired == [True, False, False, True, False, False]
    assert plan.stats()['x'] == {'hits': 6, 'fired': 2}


def test_every_schedule_fires_periodically():
    plan = FaultPlan([FaultPoint('x', 'raise', every=3)])
    fired = [plan.draw('x') is not None for _ in range(9)]
    assert fired == [False, False, True] * 3


def test_limit_caps_fires():
    plan = FaultPlan([FaultPoint('x', 'raise', every=1, limit=2)])
    fired = [plan.draw('x') is not None for _ in range(5)]
    assert fired == [True, True, False, False, False]


def test_probability_stream_is_deterministic_per_seed_and_site():
    def run(seed):
        plan = FaultPlan([FaultPoint('a', 'raise', p=0.5),
                          FaultPoint('b', 'raise', p=0.5)], seed=seed)
        return ([plan.draw('a') is not None for _ in range(64)],
                [plan.draw('b') is not None for _ in range(64)])

    a1, b1 = run(7)
    a2, b2 = run(7)
    a3, _ = run(8)
    assert a1 == a2 and b1 == b2          # same seed -> same schedule
    assert a1 != a3                       # different seed -> different
    assert a1 != b1                       # per-site independent streams
    assert any(a1) and not all(a1)


def test_site_streams_are_interleaving_invariant():
    """A site's fire pattern depends only on ITS hit order — not on
    what other sites did in between (the property that makes a chaos
    run reproducible even when thread interleavings differ)."""
    plan1 = FaultPlan([FaultPoint('a', 'raise', p=0.3)], seed=3)
    solo = [plan1.draw('a') is not None for _ in range(32)]

    plan2 = FaultPlan([FaultPoint('a', 'raise', p=0.3),
                       FaultPoint('b', 'raise', p=0.9)], seed=3)
    mixed = []
    for i in range(32):
        plan2.draw('b')                   # interleave another site
        mixed.append(plan2.draw('a') is not None)
        plan2.draw('b')
    assert solo == mixed


def test_exhausted_point_keeps_draw_sequence_invariant():
    """A limit-exhausted probabilistic point still consumes its RNG
    draw, so a second point on the site sees the same stream whether
    or not the first ran out."""
    def pattern(limit):
        plan = FaultPlan([FaultPoint('x', 'delay', p=0.5, limit=limit),
                          FaultPoint('x', 'raise', p=0.5)], seed=11)
        out = []
        for _ in range(64):
            pt = plan.draw('x')
            out.append(None if pt is None else pt.action)
        return out

    unlimited = pattern(limit=None)
    capped = pattern(limit=2)
    # after the cap, every hit where 'delay' fired in the unlimited run
    # must resolve identically for the SECOND point
    fires_seen = 0
    for u, c in zip(unlimited, capped):
        if u == 'delay':
            fires_seen += 1
            if fires_seen <= 2:
                assert c == 'delay'
        elif u == 'raise':
            assert c == 'raise'
        else:
            assert c is None


def test_skew_accumulates_into_clock():
    plan = FaultPlan([FaultPoint('policy.clock', 'skew', at=[1, 2],
                                 skew_s=10.0)])
    clock = plan.clock()
    t0 = clock()                          # hit 0: no skew yet
    t1 = clock()                          # hit 1: +10
    t2 = clock()                          # hit 2: +20
    t3 = clock()                          # hit 3: stays +20
    assert t1 - t0 > 9.0
    assert t2 - t1 > 9.0
    assert t3 - t2 < 1.0
    assert plan.skew_s() == pytest.approx(20.0)


def test_perhaps_raise_and_stall():
    plan = FaultPlan([FaultPoint('err', 'raise', at=[0], note='boom'),
                      FaultPoint('sl', 'stall', at=[0], delay_s=0.01)])
    with pytest.raises(FaultInjected) as ei:
        plan.perhaps_raise('err')
    assert ei.value.site == 'err' and 'boom' in str(ei.value)
    plan.perhaps_raise('err')             # hit 1: no fire, no raise
    assert plan.perhaps_stall('sl') == pytest.approx(0.01)
    assert plan.perhaps_stall('sl') == 0.0
    assert plan.total_fired() == 2


def test_plan_is_thread_safe_and_counts_every_hit():
    plan = FaultPlan([FaultPoint('x', 'raise', p=0.5)], seed=1)
    n_threads, per_thread = 8, 200

    def worker():
        for _ in range(per_thread):
            plan.draw('x')

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = plan.stats()['x']
    assert st['hits'] == n_threads * per_thread
    assert 0 < st['fired'] < st['hits']


def test_kill_socket_never_raises():
    a, b = socket.socketpair()
    kill_socket(a)
    kill_socket(a)                        # double-kill is fine
    assert b.recv(1) == b''               # peer observes EOF
    b.close()


# ---------------------------------------------------------------------------
# Clock-skew hardening
# ---------------------------------------------------------------------------

def test_token_bucket_survives_backward_clock():
    bkt = _TokenBucket(rate_per_s=10.0, burst=2)
    now = time.monotonic()
    assert bkt.try_take(now) == 0.0
    assert bkt.try_take(now) == 0.0       # burst of 2 spent
    wait = bkt.try_take(now)
    assert 0 < wait <= 0.1
    # a big BACKWARD step must not confiscate tokens or inflate waits
    wait_back = bkt.try_take(now - 100.0)
    assert 0 < wait_back <= 0.1
    # forward progress still refills normally
    assert bkt.try_take(now + 1.0) == 0.0


def test_rate_estimator_absorbs_backward_clock():
    est = RateEstimator(tau_s=0.5)
    est.observe(8, now=100.0)
    r = est.rate(now=100.0)
    assert r > 0
    assert est.rate(now=50.0) == pytest.approx(r)   # backward: no decay
    assert est.rate(now=101.0) < r                  # forward: decays
    est.observe(1, now=10.0)                        # backward observe
    assert est.rate(now=101.0) > 0                  # never negative/NaN


def test_adaptive_policy_decisions_stay_clamped_under_skew():
    plan = FaultPlan([FaultPoint('policy.clock', 'skew', every=3,
                                 skew_s=-50.0)])
    pol = AdaptivePolicy(max_coalesce=8, min_wait_ms=0.5, max_wait_ms=20.0,
                         clock=plan.clock())
    for _ in range(50):
        pol.observe(4)
        d = pol.decide()
        assert 1 <= d.watermark <= 8
        assert 0.5 <= d.max_wait_ms <= 20.0
        assert d.rate_per_s >= 0.0


# ---------------------------------------------------------------------------
# Dedup window
# ---------------------------------------------------------------------------

def test_dedup_new_then_redeliver_then_expire():
    now = [0.0]
    d = _DedupWindow(window_s=5.0, max_entries=16, clock=lambda: now[0])
    assert d.begin('t', 'k', 'c1', 1) == ('new', None)
    assert d.settle('t', 'k', 'TICKET') == ('c1', 1)
    status, ticket = d.begin('t', 'k', 'c2', 2)
    assert (status, ticket) == ('done', 'TICKET')   # cache, not recompute
    now[0] = 6.0                                    # window elapses
    assert d.begin('t', 'k', 'c3', 3) == ('new', None)
    info = d.info()
    assert info['hits'] == 1 and info['misses'] == 2
    assert info['redelivered'] == 1


def test_dedup_inflight_reattaches_delivery():
    d = _DedupWindow()
    d.begin('t', 'k', 'c1', 1)
    status, old = d.begin('t', 'k', 'c2', 2)
    assert status == 'inflight' and old == ('c1', 1)
    # settling delivers to the LATEST attachment
    assert d.settle('t', 'k', 'T') == ('c2', 2)
    assert d.info()['reattached'] == 1


def test_dedup_keys_are_tenant_scoped():
    d = _DedupWindow()
    d.begin('alice', 'k', 'c1', 1)
    assert d.begin('bob', 'k', 'c2', 2) == ('new', None)


def test_dedup_capacity_evicts_done_never_inflight():
    d = _DedupWindow(window_s=1e9, max_entries=2)
    d.begin('t', 'a', 'c', 1)                        # stays inflight
    d.begin('t', 'b', 'c', 2)
    d.settle('t', 'b', 'TB')
    d.begin('t', 'c', 'c', 3)                        # over capacity
    # 'b' (done) was evicted; 'a' (inflight) is pinned
    assert d.begin('t', 'b', 'c', 4) == ('new', None)
    assert d.begin('t', 'a', 'c', 5)[0] == 'inflight'


def test_dedup_forget_clears_half_registered_work():
    d = _DedupWindow()
    d.begin('t', 'k', 'c1', 1)
    d.forget('t', 'k')
    assert d.settle('t', 'k', 'T') is None
    assert d.begin('t', 'k', 'c2', 2) == ('new', None)


# ---------------------------------------------------------------------------
# Brownout breaker
# ---------------------------------------------------------------------------

def _breaker(now, **kw):
    kw.setdefault('failure_threshold', 3)
    kw.setdefault('overload_trip', 4)
    kw.setdefault('cooldown_s', 1.0)
    kw.setdefault('probe_quota', 2)
    return BrownoutBreaker(clock=lambda: now[0], **kw)


def test_breaker_trips_on_consecutive_failures_only():
    now = [0.0]
    b = _breaker(now)
    for _ in range(10):                   # interleaved successes reset
        b.record_failure()
        b.record_success()
    assert b.state == 'closed'
    for _ in range(3):
        b.record_failure()
    assert b.state == 'open'
    assert b.info()['transitions'] == {'closed_to_open': 1}


def test_breaker_sheds_only_configured_classes():
    now = [0.0]
    b = _breaker(now)
    for _ in range(3):
        b.record_failure()
    hint = b.should_shed('batch')
    assert hint is not None and hint > 0
    assert b.should_shed('interactive') is None
    assert b.should_shed('standard') is None
    assert b.info()['shed'] == 1


def test_breaker_half_open_probes_then_closes():
    now = [0.0]
    b = _breaker(now)
    for _ in range(3):
        b.record_failure()
    now[0] = 1.5                          # cooldown elapsed
    assert b.should_shed('batch') is None  # probe 1 admitted
    assert b.state == 'half_open'
    assert b.should_shed('batch') is None  # probe 2 admitted
    assert b.should_shed('batch') is not None  # quota spent: shed again
    b.record_success()
    b.record_success()
    assert b.state == 'closed'
    tr = b.info()['transitions']
    assert tr['open_to_half_open'] == 1 and tr['half_open_to_closed'] == 1


def test_breaker_half_open_failure_reopens_with_fresh_cooldown():
    now = [0.0]
    b = _breaker(now)
    for _ in range(3):
        b.record_failure()
    now[0] = 1.5
    assert b.should_shed('batch') is None
    b.record_failure()
    assert b.state == 'open'
    assert b.info()['transitions']['half_open_to_open'] == 1
    now[0] = 2.0                          # 0.5s into the NEW cooldown
    assert b.should_shed('batch') is not None
    now[0] = 2.6
    assert b.should_shed('batch') is None  # re-probes after it


def test_breaker_trips_on_sustained_overload():
    now = [0.0]
    b = _breaker(now)
    for _ in range(3):
        b.note_load(5, 6)                 # top level, but not sustained
        b.note_load(2, 6)
    assert b.state == 'closed'
    for _ in range(4):
        b.note_load(5, 6)
    assert b.state == 'open'


# ---------------------------------------------------------------------------
# Fair scheduler (weighted deficit round-robin)
# ---------------------------------------------------------------------------

def test_drr_interleaves_equal_weights():
    s = _FairScheduler(window=100)
    for i in range(4):
        s.offer('a', 1.0, f'a{i}')
    for i in range(4):
        s.offer('b', 1.0, f'b{i}')
    order = [t for t, _ in s.take()]
    assert order == ['a', 'b', 'a', 'b', 'a', 'b', 'a', 'b']


def test_drr_respects_weights():
    s = _FairScheduler(window=100)
    for i in range(8):
        s.offer('heavy', 2.0, i)
        s.offer('light', 1.0, i)
    order = [t for t, _ in s.take()]
    # over the full drain, heavy got 2 dispatches per light's 1 in
    # every rotation
    assert order[:3] == ['heavy', 'heavy', 'light']
    heavy_rank = [i for i, t in enumerate(order) if t == 'heavy']
    light_rank = [i for i, t in enumerate(order) if t == 'light']
    assert sum(heavy_rank) < sum(light_rank)


def test_drr_window_bounds_active_and_done_refills():
    s = _FairScheduler(window=2)
    for i in range(5):
        s.offer('a', 1.0, i)
    assert [x for _, x in s.take()] == [0, 1]
    assert s.take() == []                 # window full
    s.done()
    assert [x for _, x in s.take()] == [2]
    s.done()
    s.done()
    assert [x for _, x in s.take()] == [3, 4]
    assert s.queued() == 0


def test_drr_flood_cannot_starve_equal_weight_tenant():
    """The fairness bound the chaos harness asserts end-to-end: with a
    100-deep flood queued ahead of 10 requests from an equal-weight
    tenant, the tenant's requests all dispatch within the first ~2x
    its own count of slots."""
    s = _FairScheduler(window=1)
    for i in range(100):
        s.offer('flood', 1.0, i)
    for i in range(10):
        s.offer('victim', 1.0, i)
    order = []
    for _ in range(110):
        got = s.take()
        assert len(got) == 1
        order.append(got[0][0])
        s.done()
    assert order.index('victim') <= 2
    assert order[:20].count('victim') == 10


def test_drr_idle_tenant_does_not_bank_deficit():
    s = _FairScheduler(window=1)
    s.offer('a', 1000.0, 'a0')            # huge weight, single item
    assert s.take() == [('a', 'a0')]      # queue empties: deficit reset
    s.done()
    for i in range(3):
        s.offer('a', 1000.0, f'a{i + 1}')
        s.offer('b', 1.0, f'b{i}')
    seen = []
    for _ in range(6):
        seen.extend(t for t, _ in s.take())
        s.done()
    # b still gets service each rotation (weight ratio, not banked
    # deficit, governs)
    assert seen.count('b') == 3


# ---------------------------------------------------------------------------
# TenantConfig reload round-trip
# ---------------------------------------------------------------------------

def test_tenant_config_dict_round_trip():
    cfg = TenantConfig('t', rate_per_s=12.5, burst=9, max_inflight=3,
                       slo='interactive', token='s3cret', weight=2.5,
                       admin=True)
    assert TenantConfig.from_dict(cfg.to_dict()) == cfg
    inf = TenantConfig('u')               # rate defaults to inf
    d = inf.to_dict()
    assert d['rate_per_s'] is None        # JSON-safe
    assert TenantConfig.from_dict(d) == inf
    assert math.isinf(TenantConfig.from_dict({'name': 'v'}).rate_per_s)


def test_tenant_config_rejects_unknown_fields_and_bad_weight():
    with pytest.raises(ValueError):
        TenantConfig.from_dict({'name': 'x', 'mystery': 1})
    with pytest.raises(ValueError):
        TenantConfig('x', weight=0.0)
    with pytest.raises(ValueError):
        TenantConfig.from_dict({'name': 'x', 'weight': -1})
