"""Batched FFT serving engine: coalescing, tickets, the throughput
model, donated buffers, and the overlap machinery's host-level stream
pipeline.

In-process tests run on a 1x1 mesh; the 16-fake-device matrix (engine
outputs bit-identical to per-request execution, complex and real,
remainder groups, donation on a real mesh) runs in a subprocess
(tests/_serve_fft_worker.py)."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.fft as fft
from repro.comm import cost as ccost
from repro.comm import overlap as ov
from repro.serve import FFTEngine

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RNG = np.random.default_rng(29)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


# ---------------------------------------------------------------------------
# Engine correctness (1x1 mesh)
# ---------------------------------------------------------------------------

def test_engine_mixed_stream(mesh):
    shape = (8, 8, 8)
    eng = FFTEngine(shape, mesh)
    reqs = []
    for i in range(7):                        # odd count: remainder group
        x = RNG.standard_normal(shape).astype(np.float32)
        if i % 2:
            x = (x + 1j * RNG.standard_normal(shape)).astype(np.complex64)
        reqs.append(x)
    tickets = [eng.submit(x) for x in reqs]
    assert not any(t.done for t in tickets)
    outs = eng.flush()
    assert all(t.done for t in tickets)
    for x, t, o in zip(reqs, tickets, outs):
        assert t.result() is o
        got = np.asarray(t.result())
        if np.iscomplexobj(x):
            want = np.fft.fftn(x)
            assert got.shape == shape
        else:
            want = np.fft.rfftn(x)
            assert got.shape == (8, 8, 5)
        np.testing.assert_allclose(got, want,
                                   atol=3e-4 * np.max(np.abs(want)))


def test_engine_inverse_and_ticket_flush(mesh):
    shape = (8, 8)
    eng = FFTEngine(shape, mesh)
    x = (RNG.standard_normal(shape)
         + 1j * RNG.standard_normal(shape)).astype(np.complex64)
    y = eng.submit(x).result()                 # result() flushes lazily
    back = eng.transform([y], direction='inv')[0]
    np.testing.assert_allclose(np.asarray(back), x, atol=1e-4)
    # real inverse is inferred from the spectrum shape
    xr = RNG.standard_normal(shape).astype(np.float32)
    spec = eng.submit(xr).result()
    assert spec.shape == (8, 5)
    br = eng.transform([spec], direction='inv')[0]
    assert not np.iscomplexobj(np.asarray(br))
    np.testing.assert_allclose(np.asarray(br), xr, atol=1e-4)


def test_engine_validation(mesh):
    eng = FFTEngine((8, 8), mesh)
    # a rank-3 operand now plans a rank-3 transform (multi-shape
    # serving); only rank > 3 — a batch of transforms — is rejected
    with pytest.raises(ValueError, match="owns batching"):
        eng.submit(np.zeros((2, 2, 8, 8), np.complex64))
    with pytest.raises(ValueError, match="direction"):
        eng.submit(np.zeros((8, 8), np.complex64), direction='back')
    with pytest.raises(ValueError, match="real plan forward"):
        eng.submit((np.zeros((8, 8)), np.zeros((8, 8))), real=True)
    with pytest.raises(ValueError, match="pass real= explicitly"):
        eng.submit(np.zeros((3, 3), np.complex64), direction='inv')
    with pytest.raises(ValueError, match="batch_spec"):
        FFTEngine((8, 8), mesh, batch_spec='x')
    with pytest.raises(ValueError, match="mesh is required"):
        FFTEngine((8, 8))
    with pytest.raises(ValueError, match="max_coalesce"):
        FFTEngine((8, 8), mesh, max_coalesce=0)
    p = fft.plan((8, 8), mesh, batch_spec='x')
    with pytest.raises(ValueError, match="batch_spec"):
        FFTEngine(p)


def test_engine_from_existing_plan(mesh):
    p = fft.rplan((8, 8, 8), mesh, method='stockham')
    eng = FFTEngine(p)
    assert eng.shape == (8, 8, 8)
    sp = eng.plan_for(True)
    assert sp.real and sp.method == 'stockham'
    # the complex sibling adopts the resolved settings
    cp = eng.plan_for(False)
    assert not cp.real and cp.method == 'stockham'
    x = RNG.standard_normal((8, 8, 8)).astype(np.float32)
    got = np.asarray(eng.transform([x])[0])
    want = np.fft.rfftn(x)
    np.testing.assert_allclose(got, want, atol=3e-4 * np.max(np.abs(want)))


def test_engine_schedule_knobs(mesh):
    eng = FFTEngine((8, 8, 8), mesh, max_coalesce=4, overlap_chunks=2)
    w, c = eng.schedule(False)
    assert 1 <= w <= 4 and c in (1, 2)
    # a latency budget of ~zero forces the un-coalesced schedule
    eng2 = FFTEngine((8, 8, 8), mesh, latency_budget_us=1e-9)
    assert eng2.schedule(False) == (1, 1)


def test_engine_executable_cache_shared(mesh):
    eng = FFTEngine((8, 8), mesh, max_coalesce=4)
    w, _ = eng.schedule(False)
    reqs = [(RNG.standard_normal((8, 8))
             + 1j * RNG.standard_normal((8, 8))).astype(np.complex64)
            for _ in range(2 * w)]
    eng.transform(reqs)
    p = eng.plan_for(False)
    n0 = len(p._exec_cache)
    eng.transform(reqs)                        # same widths -> no retrace
    assert len(p._exec_cache) == n0


def test_flush_failure_requeues_instead_of_silent_none(mesh, monkeypatch):
    """A failed group must not drop its tickets: the entries go back on
    the queue, result() re-raises (never returns a silent None), and a
    retry after the fault clears succeeds."""
    eng = FFTEngine((8, 8), mesh)
    x = (RNG.standard_normal((8, 8))
         + 1j * RNG.standard_normal((8, 8))).astype(np.complex64)
    t = eng.submit(x)

    def boom(*a, **k):
        raise RuntimeError("boom")

    monkeypatch.setattr(eng, '_run_group', boom)
    with pytest.raises(RuntimeError, match="boom"):
        eng.flush()
    assert not t.done
    assert sum(len(q) for q in eng._queues.values()) == 1
    with pytest.raises(RuntimeError, match="boom"):   # retried, re-raised
        t.result()
    monkeypatch.undo()
    got = np.asarray(t.result())                      # retry succeeds
    np.testing.assert_allclose(got, np.fft.fftn(x), atol=1e-3)


def test_engine_autotune(mesh):
    eng = FFTEngine((8, 8), mesh, max_coalesce=2)
    reqs = [(RNG.standard_normal((8, 8))
             + 1j * RNG.standard_normal((8, 8))).astype(np.complex64)
            for _ in range(4)]
    w, c = eng.autotune(reqs, repeats=1, widths=(1, 2), chunks=(1, 2))
    assert eng.schedule(False) == (w, c)
    assert w in (1, 2) and c in (1, 2)
    got = np.asarray(eng.transform([reqs[0]])[0])
    np.testing.assert_allclose(got, np.fft.fftn(reqs[0]), atol=1e-3)


# ---------------------------------------------------------------------------
# Persisted serving schedules (BENCH_serve_schedule.json)
# ---------------------------------------------------------------------------

def test_schedule_table_lookup_prefers_dtype():
    rows = [dict(mesh='4x4', shape='8x8', kind='complex',
                 strategy='all_to_all', dtype='complex64',
                 coalesce_width=8, overlap_chunks=2, us_per_request=10.0),
            dict(mesh='4x4', shape='8x8', kind='complex',
                 strategy='all_to_all', dtype='complex128',
                 coalesce_width=4, overlap_chunks=4, us_per_request=5.0)]
    tbl = ccost.ScheduleTable(rows)
    mesh_shape = {'x': 4, 'y': 4}
    got = tbl.lookup(mesh_shape, (8, 8), 'complex', 'all_to_all',
                     dtype='complex64')
    assert (got['coalesce_width'], got['overlap_chunks']) == (8, 2)
    # unmeasured dtype: the fastest row of the key answers
    got = tbl.lookup(mesh_shape, (8, 8), 'complex', 'all_to_all',
                     dtype='float32')
    assert got['coalesce_width'] == 4
    assert tbl.lookup(mesh_shape, (8, 8), 'real', 'all_to_all') is None
    assert tbl.lookup({'x': 2}, (8, 8), 'complex', 'all_to_all') is None


def test_schedule_table_backend_isolation():
    """Rows from different backends merge independently and never
    answer for each other — a CPU refresh must not clobber or shadow a
    GPU host's persisted measurement."""
    mk = dict(mesh='4x4', shape='8x8', kind='complex',
              strategy='all_to_all', dtype='complex64')
    tbl = ccost.ScheduleTable([
        dict(mk, coalesce_width=4, overlap_chunks=2, us_per_request=1.0,
             backend='gpu'),
        dict(mk, coalesce_width=2, overlap_chunks=1, us_per_request=9.0,
             backend='cpu')])
    assert len(tbl) == 2                       # same config, both survive
    mesh_shape = {'x': 4, 'y': 4}
    got = tbl.lookup(mesh_shape, (8, 8), 'complex', 'all_to_all',
                     backend='cpu')
    assert got['coalesce_width'] == 2          # never the gpu row
    got = tbl.lookup(mesh_shape, (8, 8), 'complex', 'all_to_all',
                     backend='tpu')
    assert got is None                         # unmeasured backend: model


def test_autotune_persists_and_seeds_next_engine(mesh, tmp_path):
    path = str(tmp_path / "BENCH_serve_schedule.json")
    eng = FFTEngine((8, 8), mesh, max_coalesce=2, schedule_table=path)
    reqs = [(RNG.standard_normal((8, 8))
             + 1j * RNG.standard_normal((8, 8))).astype(np.complex64)
            for _ in range(4)]
    w, c = eng.autotune(reqs, repeats=1, widths=(1, 2), chunks=(1, 2),
                        persist=True)
    assert os.path.exists(path)
    tbl = ccost.ScheduleTable.load(path)
    row = tbl.lookup(dict(mesh.shape), (8, 8), 'complex',
                     eng.plan_for(False).comm, dtype='complex64')
    assert (row['coalesce_width'], row['overlap_chunks']) == (w, c)
    assert row['us_per_request'] > 0
    # a NEW engine on the same config seeds its pick from the table...
    eng2 = FFTEngine((8, 8), mesh, max_coalesce=2, schedule_table=path)
    assert eng2.schedule(False) == (w, c)
    # ...still serving correctly
    got = np.asarray(eng2.transform([reqs[0]])[0])
    np.testing.assert_allclose(got, np.fft.fftn(reqs[0]), atol=1e-3)
    # an engine whose knobs the row does not fit falls back to the model
    eng3 = FFTEngine((8, 8), mesh, max_coalesce=max(w - 1, 1),
                     schedule_table=path)
    w3, _ = eng3.schedule(False)
    assert w3 <= max(w - 1, 1)


def test_schedule_table_env_override(mesh, tmp_path, monkeypatch):
    path = str(tmp_path / "alt_schedules.json")
    ccost.persist_schedule_rows(
        [dict(mesh='1x1', shape='8x8', kind='complex',
              strategy='all_to_all', dtype='complex64', coalesce_width=2,
              overlap_chunks=1, us_per_request=1.0)], path)
    monkeypatch.setenv(ccost.SCHEDULE_ENV, path)
    eng = FFTEngine((8, 8), mesh, max_coalesce=4, comm='all_to_all')
    assert eng.schedule(False) == (2, 1)       # seeded from the env table
    monkeypatch.setenv(ccost.SCHEDULE_ENV, '')  # '' disables persistence
    assert ccost.schedule_table_path() is None
    assert ccost.persist_schedule_rows([]) is None


# ---------------------------------------------------------------------------
# Donation semantics (the no-reuse-after-donate contract)
# ---------------------------------------------------------------------------

def test_donated_plan_consumes_input(mesh):
    p = fft.plan((8, 8), mesh)
    assert p.donate and p.donates_input
    x = jnp.asarray(RNG.standard_normal((8, 8)), jnp.complex64)
    y = p.forward(x)
    assert x.is_deleted()
    with pytest.raises(RuntimeError, match="deleted"):
        _ = x + 1
    # the output is alive; the inverse consumes IT in turn
    back = p.inverse(y)
    assert y.is_deleted()
    assert not back.is_deleted()


def test_donate_false_escape_hatch(mesh):
    p = fft.plan((8, 8), mesh, donate=False)
    assert not p.donates_input
    x = jnp.asarray(RNG.standard_normal((8, 8)), jnp.complex64)
    y1 = p.forward(x)
    y2 = p.forward(x)                          # reusable FFTW-style buffer
    assert not x.is_deleted()
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_planar_donation_consumes_both(mesh):
    p = fft.plan((8, 8), mesh)
    re = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    im = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    p.forward((re, im))
    assert re.is_deleted() and im.is_deleted()


def test_real_plans_never_donate(mesh):
    p = fft.rplan((8, 8), mesh)
    assert p.donate and not p.donates_input    # requested but structurally n/a
    x = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    y = p.forward(x)
    assert not x.is_deleted()
    p.inverse(y)
    assert not y.is_deleted()


def test_engine_donation_follows_plan_contract(mesh):
    # donate=True: submitted jax arrays are consumed (same contract as
    # plan.forward), each request aliasing its own output in the group
    eng = FFTEngine((8, 8), mesh)
    assert eng.donate
    x = jnp.asarray(RNG.standard_normal((8, 8)), jnp.complex64)
    eng.transform([x])
    assert x.is_deleted()
    # numpy submissions are copied to device — caller data untouched
    xnp = RNG.standard_normal((8, 8)).astype(np.complex64)
    ref = xnp.copy()
    y = eng.transform([xnp])[0]
    assert np.array_equal(xnp, ref)            # unmodified and readable
    np.testing.assert_allclose(np.asarray(y), np.fft.fftn(ref), atol=1e-3)
    # donate=False escape hatch keeps submitted jax arrays alive
    eng2 = FFTEngine((8, 8), mesh, donate=False)
    x2 = jnp.asarray(RNG.standard_normal((8, 8)), jnp.complex64)
    eng2.transform([x2])
    assert not x2.is_deleted()
    # real requests are never donated (no aliasing across r2c)
    xr = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    eng.transform([xr])
    assert not xr.is_deleted()


def test_with_options_carries_donate(mesh):
    p = fft.plan((8, 8), mesh, donate=False)
    assert not p.with_options(overlap_chunks=2).donates_input
    assert p.with_options(donate=True).donates_input


def test_with_options_real_to_complex_drops_padded(mesh):
    """padded_spectrum is a real-plan-only knob: a real -> complex
    re-plan must drop it instead of tripping plan() validation."""
    p = fft.rplan((8, 8), mesh, padded_spectrum=True)
    c = p.with_options(real=False)
    assert not c.real and not c.padded_spectrum
    # and a round trip back to real keeps working
    r = c.with_options(real=True, padded_spectrum=True)
    assert r.real and r.padded_spectrum


# ---------------------------------------------------------------------------
# Throughput model + stream pipeline machinery
# ---------------------------------------------------------------------------

def test_pipeline_model():
    pc = ccost.pencil_plan_cost((64,) * 3, ('x', 'y', None),
                                {'x': 8, 'y': 8}, measured=None)
    # one request, one chunk: exactly the serial schedule
    assert pc.pipeline_cycles(1) == pytest.approx(pc.serial_cycles)
    assert pc.pipeline_cycles(4, 1) == pytest.approx(4 * pc.serial_cycles)
    # coalescing strictly improves per-request cost...
    assert pc.pipeline_us(8) < pc.pipeline_us(1)
    # ...approaching the steady-state bound max(compute, wire)/request
    comp = pc.serial_cycles - pc.wire_cycles
    bound = max(comp, pc.wire_cycles)
    assert pc.pipeline_cycles(64) / 64 > bound
    assert pc.pipeline_cycles(64) / 64 < 1.2 * bound + ccost.OVERLAP_CHUNK_OVERHEAD
    # ...while whole-batch latency grows
    assert pc.pipeline_latency_us(8) > pc.pipeline_latency_us(2)
    # priced per strategy: a different wire schedule changes the
    # fill/drain term, so the throughput curve moves with the strategy
    ring = ccost.pencil_plan_cost((64,) * 3, ('x', 'y', None),
                                  {'x': 8, 'y': 8}, strategy='ppermute',
                                  measured=None)
    assert ring.wire_cycles != pc.wire_cycles
    assert ring.pipeline_us(8) != pc.pipeline_us(8)


def test_pipelined_stream_order_and_depth():
    calls = []

    def fn(x):
        calls.append(x)
        return jnp.asarray(x * 2.0)

    out = ov.pipelined_stream(fn, [1.0, 2.0, 3.0, 4.0, 5.0], depth=2)
    assert calls == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert [float(o) for o in out] == [2.0, 4.0, 6.0, 8.0, 10.0]
    assert ov.pipelined_stream(fn, []) == []
    with pytest.raises(ValueError, match="depth"):
        ov.pipelined_stream(fn, [1.0], depth=0)


def test_pick_chunk_axis_fallbacks():
    # no overlap requested
    assert ov.pick_chunk_axis((8, 8), (), 1) is None
    # every axis excluded
    assert ov.pick_chunk_axis((8, 8), (0, 1), 2) is None
    # nothing divides
    assert ov.pick_chunk_axis((4, 4, 16), (), 3) is None
    # n_chunks larger than every free axis
    assert ov.pick_chunk_axis((4, 4), (0,), 8) is None
    # first qualifying axis wins (leading batch axis preferred)
    assert ov.pick_chunk_axis((8, 4, 16), (1,), 4) == 0
    assert ov.pick_chunk_axis((3, 4, 16), (1,), 4) == 2


# ---------------------------------------------------------------------------
# 16-device matrix (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_fft_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_SERVE_SCHEDULES"] = ""          # deterministic picks
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_serve_fft_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stdout[-4000:] + "\n" + proc.stderr[-4000:]
    assert "SERVE_FFT_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 6
