"""Properties of the serving layer via hypothesis (optional dev
dependency; the whole module is skipped when hypothesis is not
installed — deterministic coverage of the same machinery lives in
test_serve_fft.py / test_serve_drainer.py).

Covered invariants:

* the serving throughput model: steady-state ``pipeline_us`` is
  monotone non-increasing in the coalesce width (until a latency
  budget binds, which the schedule picker must respect),
* the LRU plan cache: never exceeds its byte budget, eviction order is
  least-recently-used, and a re-requested key rebuilds at most once
  per eviction,
* the persisted schedule table: merge replaces same-key rows and keeps
  the rest, and save/load round-trips exactly.
"""
import os
import tempfile

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import cost as ccost  # noqa: E402
from repro.serve import FFTEngine, LRUPlanCache  # noqa: E402

# ---------------------------------------------------------------------------
# Throughput model: pipeline_us monotone in width; budget binds the pick
# ---------------------------------------------------------------------------

_MESHES = st.sampled_from([{'x': 2, 'y': 2}, {'x': 4, 'y': 4},
                           {'x': 2, 'y': 8}])
_STRATEGIES = st.sampled_from(['all_to_all', 'ppermute', 'hierarchical'])


def _best_us(pc, w):
    """The picker's view of one width: the best feasible chunk depth."""
    return min(pc.pipeline_us(w, c) for c in (1, 2, 4, 8, 16)
               if c <= w and w % c == 0)


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([16, 32, 64]), mesh=_MESHES,
       strategy=_STRATEGIES, real=st.booleans(),
       chunks=st.sampled_from([1, 2, 4]))
def test_pipeline_us_monotone_in_width(n, mesh, strategy, real, chunks):
    """Coalescing more requests never costs more per request in steady
    state — at a FIXED chunk depth (the batch amortizes the per-chunk
    dispatch overhead), and for the best-over-chunks schedule the
    picker optimizes (a power-of-two width's divisors nest). One chunk
    per request (``overlap_chunks=None``) is deliberately excluded:
    there the chunk overhead grows with the batch, which is exactly why
    the picker searches (width, chunks) jointly."""
    pc = ccost.pencil_plan_cost((n, n, n), ('x', 'y', None), mesh,
                                strategy=strategy, real=real,
                                measured=None)
    widths = [w for w in (1, 2, 4, 8, 16, 32, 64) if w >= chunks]
    for prev_w, w in zip(widths, widths[1:]):
        assert (pc.pipeline_us(w, chunks)
                <= pc.pipeline_us(prev_w, chunks) * (1 + 1e-9) + 1e-9)
    best = [_best_us(pc, w) for w in (1, 2, 4, 8, 16, 32, 64)]
    for prev, cur in zip(best, best[1:]):
        assert cur <= prev * (1 + 1e-9) + 1e-9
    # and the whole-batch latency grows with the batch, so a latency
    # budget must eventually bind the width
    assert (pc.pipeline_latency_us(64, chunks)
            > pc.pipeline_latency_us(1, chunks))


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([16, 64]), maxc=st.integers(1, 32),
       budget=st.one_of(st.none(), st.floats(0.5, 1e5)))
def test_schedule_pick_respects_knobs(n, maxc, budget):
    """The engine's (width, chunks) pick: width within max_coalesce,
    chunks dividing the width, the latency budget honored whenever any
    coalesced schedule can honor it, and the steady-state objective
    never worse than the un-coalesced schedule."""
    sharding = pytest.importorskip("jax.sharding")
    if not hasattr(sharding, 'AbstractMesh'):
        pytest.skip("jax.sharding.AbstractMesh unavailable")
    mesh = sharding.AbstractMesh((('x', 4), ('y', 4)))
    eng = FFTEngine((n, n, n), mesh, max_coalesce=maxc,
                    latency_budget_us=budget, schedule_table=None)
    w, c = eng.schedule(False)
    assert 1 <= w <= maxc and 1 <= c <= w and w % c == 0
    pc = eng.plan_for(False).plan_cost()
    if budget is not None and (w, c) != (1, 1):
        assert pc.pipeline_latency_us(w, c) <= budget
    assert pc.pipeline_us(w, c) <= pc.pipeline_us(1, 1) * (1 + 1e-9)


# ---------------------------------------------------------------------------
# LRU plan cache
# ---------------------------------------------------------------------------

_KEYS = 'abcde'


@settings(max_examples=60, deadline=None)
@given(budget=st.integers(60, 200),
       ops=st.lists(st.tuples(st.sampled_from(_KEYS),
                              st.integers(1, 60)),
                    min_size=1, max_size=60))
def test_lru_cache_budget_order_rebuilds(budget, ops):
    """Get-or-build over a byte-budgeted cache (every entry fits the
    budget alone): the cache never exceeds its budget, the key just
    served always survives, surviving keys keep exact recency order,
    and a key rebuilds at most once per eviction."""
    evicted = []
    cache = LRUPlanCache(max_bytes=budget,
                         on_evict=lambda k, v: evicted.append(k))
    recency = []                       # oldest first, surviving keys
    builds = {k: 0 for k in _KEYS}
    for key, size in ops:
        if cache.get(key) is None:
            builds[key] += 1
            cache.put(key, object(), nbytes=size)
        if key in recency:
            recency.remove(key)
        recency.append(key)
        recency = [k for k in recency if k in cache]
        assert cache.total_bytes <= budget
        assert key in cache            # the entry in use is never evicted
        assert cache.keys() == recency  # eviction order is exactly LRU
        assert cache.get(key) is not None   # immediate re-request hits
    for k in _KEYS:                    # at most one (re)build per residency
        assert builds[k] <= evicted.count(k) + 1


@settings(max_examples=40, deadline=None)
@given(cap=st.integers(1, 4),
       ops=st.lists(st.sampled_from(_KEYS), min_size=1, max_size=40))
def test_lru_cache_entry_cap(cap, ops):
    cache = LRUPlanCache(max_entries=cap)
    for key in ops:
        if cache.get(key) is None:
            cache.put(key, key)
        assert len(cache) <= cap
        assert cache.get(key) == key


# ---------------------------------------------------------------------------
# Persisted serving-schedule table
# ---------------------------------------------------------------------------

_ROW = st.fixed_dictionaries(dict(
    mesh=st.sampled_from(['4x4', '2x8']),
    shape=st.sampled_from(['16x16', '8x8x8']),
    kind=st.sampled_from(['complex', 'real']),
    strategy=st.sampled_from(['all_to_all', 'ppermute']),
    dtype=st.sampled_from([None, 'complex64', 'float32']),
    coalesce_width=st.integers(1, 32),
    overlap_chunks=st.integers(1, 8),
    us_per_request=st.floats(0.1, 1e4),
))


@settings(max_examples=40, deadline=None)
@given(rows=st.lists(_ROW, max_size=16))
def test_schedule_table_merge_and_roundtrip(rows):
    """Merging row-by-row equals merging at once; the LAST row of each
    key wins (the --refresh replace-same-key contract); save/load
    round-trips exactly."""
    tbl = ccost.ScheduleTable(rows)
    inc = ccost.ScheduleTable()
    for r in rows:
        inc.merge([r])
    assert tbl.rows() == inc.rows()
    key_of = ccost.ScheduleTable._row_key
    for r in tbl.rows():
        last = [x for x in rows if key_of(x) == key_of(r)][-1]
        assert r['coalesce_width'] == int(last['coalesce_width'])
        assert r['overlap_chunks'] == int(last['overlap_chunks'])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, 'BENCH_serve_schedule.json')
        tbl.save(path)
        back = ccost.ScheduleTable.load(path)
        if len(tbl):
            assert back is not None and back.rows() == tbl.rows()
        else:
            assert back is None     # empty tables never shadow the model
