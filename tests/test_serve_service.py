"""The multi-tenant FFT service stack: wire protocol, adaptive drainer
policy, admission control / SLO / backpressure semantics, and the
engine+cache seams they ride on.

In-process tests run on a 1x1 mesh over real unix sockets (handshake,
round trips, typed RETRY_AFTER, token auth, metrics, drain). The
16-fake-device matrix — 3 tenants x mixed shapes/kinds bit-identical
to direct plan execution, quota saturation isolation, SLO-class
ordering — runs in a subprocess (tests/_serve_service_worker.py)."""
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest
import jax

from repro.comm import cost as ccost
from repro.serve import (AdaptivePolicy, FFTClient, FFTEngine, FFTService,
                         LRUPlanCache, RateEstimator, ResultTimeout,
                         RetryAfter, SLOClass, TenantConfig)
from repro.serve import protocol as proto

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RNG = np.random.default_rng(29)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


@pytest.fixture()
def sock_path(tmp_path):
    return str(tmp_path / "fft.sock")


def _creq(shape):
    return (RNG.standard_normal(shape)
            + 1j * RNG.standard_normal(shape)).astype(np.complex64)


# ---------------------------------------------------------------------------
# Protocol: frame round trips and rejections
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", sorted(proto.WIRE_DTYPES))
def test_frame_round_trip_every_wire_dtype(dtype):
    x = np.arange(24, dtype=dtype).reshape(2, 3, 4)
    buf = proto.pack_frame(proto.SUBMIT, {'req_id': 7, 'direction': 'fwd'},
                           [x])
    msg_type, meta, arrays, consumed = proto.unpack_frame(buf)
    assert consumed == len(buf)
    assert msg_type == proto.SUBMIT
    assert meta == {'req_id': 7, 'direction': 'fwd'}
    assert arrays[0].dtype == np.dtype(dtype)
    np.testing.assert_array_equal(arrays[0], x)


def test_frame_round_trip_forms():
    # no arrays, one array, planar pair, scalar-shaped array
    for arrays in ([], [np.array(3.5, dtype=np.float32)],
                   [_creq((4, 4))],
                   [RNG.standard_normal((4, 4)).astype(np.float32),
                    RNG.standard_normal((4, 4)).astype(np.float32)]):
        buf = proto.pack_frame(proto.RESULT, {'req_id': 1}, arrays)
        _, _, out, _ = proto.unpack_frame(buf)
        assert len(out) == len(arrays)
        for a, b in zip(arrays, out):
            np.testing.assert_array_equal(np.asarray(a), b)


def test_decoded_arrays_are_zero_copy_read_only():
    buf = proto.pack_frame(proto.RESULT, {}, [_creq((8, 8))])
    _, _, [a], _ = proto.unpack_frame(buf)
    assert not a.flags.writeable
    with pytest.raises((ValueError, RuntimeError)):
        a[0, 0] = 0


def test_truncated_frames_rejected():
    buf = proto.pack_frame(proto.SUBMIT, {'req_id': 1}, [_creq((4, 4))])
    for cut in (3, proto._HEADER.size - 1, proto._HEADER.size + 2,
                len(buf) - 1):
        with pytest.raises(proto.ProtocolError, match="truncated"):
            proto.unpack_frame(buf[:cut])


def test_version_mismatch_is_typed():
    buf = bytearray(proto.pack_frame(proto.HELLO, {'tenant': 'a'}))
    buf[4] = proto.PROTOCOL_VERSION + 1      # the version byte
    with pytest.raises(proto.VersionMismatch):
        proto.unpack_frame(bytes(buf))
    # and VersionMismatch IS a ProtocolError (one except clause catches
    # both when the caller does not care)
    assert issubclass(proto.VersionMismatch, proto.ProtocolError)


def test_bad_magic_rejected():
    buf = bytearray(proto.pack_frame(proto.HELLO, {}))
    buf[:4] = b'EVIL'
    with pytest.raises(proto.ProtocolError, match="magic"):
        proto.unpack_frame(bytes(buf))


def test_non_wire_dtypes_rejected_both_ways():
    with pytest.raises(proto.ProtocolError, match="not wire-safe"):
        proto.encode_arrays([np.array(['a', 'b'])])
    with pytest.raises(proto.ProtocolError, match="not wire-safe"):
        proto.encode_arrays([np.array([object()])])
    # a frame *declaring* a non-wire dtype is rejected on decode even
    # though the bytes themselves are innocuous
    with pytest.raises(proto.ProtocolError, match="non-wire dtype"):
        proto.decode_arrays([{'dtype': 'object', 'shape': [1],
                              'nbytes': 8}], b'\0' * 8, 0)


def test_lying_descriptors_rejected():
    with pytest.raises(proto.ProtocolError, match="claims"):
        proto.decode_arrays([{'dtype': 'float32', 'shape': [4],
                              'nbytes': 12}], b'\0' * 12, 0)
    with pytest.raises(proto.ProtocolError, match="trailing"):
        proto.decode_arrays([{'dtype': 'float32', 'shape': [2],
                              'nbytes': 8}], b'\0' * 12, 0)
    with pytest.raises(proto.ProtocolError, match="negative"):
        proto.decode_arrays([{'dtype': 'float32', 'shape': [-2],
                              'nbytes': 8}], b'\0' * 8, 0)


def test_oversize_frame_rejected_without_allocation():
    head = proto._HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                              proto.SUBMIT, 0, proto.MAX_FRAME_BYTES + 1)
    with pytest.raises(proto.ProtocolError, match="cap"):
        proto._parse_header(head)


def test_socket_eof_semantics():
    a, b = socket.socketpair()
    # clean close at a frame boundary: None, not an exception
    frame = proto.pack_frame(proto.HELLO, {'tenant': 't'})
    a.sendall(frame)
    a.close()
    assert proto.recv_frame(b)[0] == proto.HELLO
    assert proto.recv_frame(b) is None
    b.close()
    # EOF mid-frame: a typed truncation error
    a, b = socket.socketpair()
    a.sendall(frame[:len(frame) - 3])
    a.close()
    with pytest.raises(proto.ProtocolError, match="EOF|truncated"):
        proto.recv_frame(b)
    b.close()


# ---------------------------------------------------------------------------
# Adaptive policy: rate estimator + decisions + persistence
# ---------------------------------------------------------------------------

def test_rate_estimator_monotone_in_events():
    t0 = 1000.0
    a, b = RateEstimator(tau_s=0.5), RateEstimator(tau_s=0.5)
    a.observe(5, t0)
    b.observe(9, t0)
    assert b.rate(t0) > a.rate(t0)
    # more events at the same instant never lower the estimate
    r_before = a.rate(t0)
    a.observe(1, t0)
    assert a.rate(t0) > r_before


def test_rate_estimator_decays_while_idle():
    est = RateEstimator(tau_s=0.5)
    est.observe(50, 1000.0)
    r0 = est.rate(1000.0)
    r1 = est.rate(1000.5)
    r2 = est.rate(1002.0)
    assert r0 > r1 > r2 > 0
    assert RateEstimator().rate() == 0.0     # before any observation


def test_rate_estimator_converges_to_arrival_rate():
    est = RateEstimator(tau_s=0.5)
    for i in range(2000):                    # 100 events/s for 20s
        est.observe(1, 1000.0 + i * 0.01)
    assert est.rate(1020.0) == pytest.approx(100.0, rel=0.1)


def test_policy_never_exceeds_max_coalesce():
    pol = AdaptivePolicy(max_coalesce=8, max_wait_ms=50.0)
    t = 1000.0
    for burst in (0, 1, 10, 1000, 100000):
        pol.observe(burst, t)
        d = pol.decide(t)
        assert 1 <= d.watermark <= 8
        assert (pol.min_wait_ms <= d.max_wait_ms <= pol.max_wait_ms)
        t += 0.001
    # even a seeded row beyond the cap is clamped
    pol2 = AdaptivePolicy(max_coalesce=4)
    pol2._levels[2] = (64, 10.0)
    pol2.observe(100000, t)
    assert pol2.decide(t).watermark <= 4


def test_policy_load_levels_monotone_in_rate():
    pol = AdaptivePolicy(max_coalesce=16, max_wait_ms=50.0)
    rates = [0.0, 10.0, 100.0, 1000.0, 100000.0]
    levels = [pol.load_level(r) for r in rates]
    assert levels == sorted(levels)
    assert levels[0] == 0
    assert levels[-1] == pol.n_levels - 1


def test_policy_rows_persist_and_seed_round_trip(tmp_path):
    path = str(tmp_path / "sched.json")
    pol = AdaptivePolicy(max_coalesce=16, max_wait_ms=50.0)
    t = 1000.0
    for burst in (0, 40, 4000):              # visit several load levels
        pol.observe(burst, t)
        pol.decide(t)
        pol.note_latency(123.0, t)
        t += 0.0005
    rows = pol.rows({'x': 4, 'y': 4}, (32, 32), 'complex', 'auto',
                    backend='cpu')
    assert len(rows) >= 2
    assert all(isinstance(r['load'], int) for r in rows)
    ccost.persist_schedule_rows(rows, path)

    table = ccost.ScheduleTable.load(path)
    fresh = AdaptivePolicy(max_coalesce=16, max_wait_ms=50.0)
    seeded = fresh.seed(table, {'x': 4, 'y': 4}, (32, 32), 'complex',
                        'auto', backend='cpu')
    assert seeded == len(rows)
    assert fresh._levels == pol._levels
    # the engine's load-less lookup NEVER sees policy rows: the load
    # tag separates the namespaces
    assert table.lookup({'x': 4, 'y': 4}, (32, 32), 'complex',
                        'auto') is None


def test_schedule_table_load_keyed_lookup():
    base = dict(mesh='4x4', shape='32x32', kind='complex',
                strategy='auto', overlap_chunks=1)
    table = ccost.ScheduleTable([
        dict(base, coalesce_width=2, us_per_request=10.0),
        dict(base, coalesce_width=4, load=1, us_per_request=20.0),
        dict(base, coalesce_width=8, load=3, us_per_request=30.0),
    ])
    ms, sh = {'x': 4, 'y': 4}, (32, 32)
    # load=None -> only the untagged row
    assert table.lookup(ms, sh, 'complex', 'auto')['coalesce_width'] == 2
    # exact tagged level
    assert table.lookup(ms, sh, 'complex', 'auto',
                        load=1)['coalesce_width'] == 4
    # nearest tagged level when the exact one is absent
    assert table.lookup(ms, sh, 'complex', 'auto',
                        load=2)['coalesce_width'] == 4
    assert table.lookup(ms, sh, 'complex', 'auto',
                        load=7)['coalesce_width'] == 8
    # tagged query with only untagged rows: fall back, never miss
    t2 = ccost.ScheduleTable([dict(base, coalesce_width=2)])
    assert t2.lookup(ms, sh, 'complex', 'auto',
                     load=3)['coalesce_width'] == 2


# ---------------------------------------------------------------------------
# Satellite regressions: cache poison, ticket timeout, dead drainer
# ---------------------------------------------------------------------------

def test_lru_on_evict_exception_does_not_poison_cache():
    calls = []

    def bad_hook(key, value):
        calls.append(key)
        raise RuntimeError("hook boom")

    cache = LRUPlanCache(max_entries=2, on_evict=bad_hook)
    cache.put('a', 1, nbytes=10)
    cache.put('b', 2, nbytes=10)
    with pytest.warns(RuntimeWarning, match="on_evict hook failed"):
        cache.put('c', 3, nbytes=10)         # evicts 'a', hook raises
    assert calls == ['a']
    assert cache.evict_errors == 1 and cache.evictions == 1
    # the cache is NOT poisoned: entry gone, bytes consistent, still
    # serving inserts and evictions
    assert 'a' not in cache and cache.total_bytes == 20
    with pytest.warns(RuntimeWarning):
        cache.put('d', 4, nbytes=10)
    assert cache.keys() == ['c', 'd'] and cache.total_bytes == 20


def test_lru_on_evict_exception_under_byte_budget():
    cache = LRUPlanCache(max_bytes=100,
                         on_evict=lambda k, v: 1 / 0)
    cache.put('a', 1, nbytes=60)
    cache.grow('a', 50)                      # alone over budget: spared,
    assert 'a' in cache                      # no eviction, no hook call
    with pytest.warns(RuntimeWarning, match="on_evict hook failed"):
        cache.put('b', 2, nbytes=60)         # now eviction fires + raises
    assert cache.keys() == ['b'] and cache.total_bytes == 60
    assert cache.evict_errors == 1


def test_result_timeout_is_typed_and_ticket_stays_valid(mesh):
    with FFTEngine((8, 8), mesh, watermark=10**6,
                   schedule_table=None) as eng:
        x = _creq((8, 8))
        t = eng.submit(x)                    # watermark never trips
        with pytest.raises(ResultTimeout):
            t.result(timeout=0.05)
        assert issubclass(ResultTimeout, TimeoutError)
        assert not t.done and not t.failed   # still queued, still valid
        eng.flush()                          # now serve it
        np.testing.assert_allclose(np.asarray(t.result(timeout=60)),
                                   np.fft.fftn(x), atol=1e-3)


def test_submit_raises_when_drainer_died_without_error(mesh):
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, schedule_table=None)
    orig = eng._drainer
    try:
        dead = threading.Thread(target=lambda: None)
        dead.start()
        dead.join()
        eng._drainer = dead                  # simulate a silent death
        with pytest.raises(RuntimeError, match="not running"):
            eng.submit(_creq((8, 8)))
    finally:
        eng._drainer = orig
        eng.close()


def test_submit_raises_after_drainer_crash_reported(mesh):
    eng = FFTEngine((8, 8), mesh, max_wait_ms=5.0, schedule_table=None)
    try:
        eng._drainer_error = RuntimeError("injected crash")
        with pytest.raises(RuntimeError, match="drainer died"):
            eng.submit(_creq((8, 8)))
    finally:
        eng._drainer_error = None
        eng.close()


# ---------------------------------------------------------------------------
# Service over a unix socket (1x1 mesh)
# ---------------------------------------------------------------------------

def test_service_round_trip_complex_real_planar(mesh, sock_path):
    with FFTService(mesh, schedule_table=None).start(sock_path) as svc:
        with svc.local_client('t0') as c:
            xc = _creq((8, 8))
            yc = c.transform([xc])[0]
            np.testing.assert_allclose(yc, np.fft.fftn(xc), atol=1e-3)

            xr = RNG.standard_normal((8, 8)).astype(np.float32)
            yr = c.transform([xr], real=True)[0]
            assert yr.shape == (8, 5)        # half spectrum on the wire
            np.testing.assert_allclose(yr, np.fft.rfftn(xr), atol=1e-3)

            re = RNG.standard_normal((8, 8)).astype(np.float32)
            im = RNG.standard_normal((8, 8)).astype(np.float32)
            ore, oim = c.transform([(re, im)])[0]
            np.testing.assert_allclose(
                ore + 1j * oim, np.fft.fftn(re + 1j * im), atol=1e-3)

            # inverse round trip through the service
            xi = c.transform([yc], direction='inv', real=False)[0]
            np.testing.assert_allclose(xi, xc, atol=1e-3)
            c.drain(timeout=60)


def test_service_retry_after_on_tenant_quota(mesh, sock_path):
    slos = {'hold': SLOClass('hold', deadline_ms=60000, max_wait_ms=800)}
    svc = FFTService(
        mesh, schedule_table=None, policy=None, watermark=10**6,
        tenants=[TenantConfig('cap1', max_inflight=1, slo='hold')],
        slo_classes=slos,
    ).start(sock_path)
    with svc, svc.local_client('cap1') as c:
        x = _creq((8, 8))
        t1 = c.submit(x)                     # held by the huge watermark
        t2 = c.submit(x)                     # quota: typed backpressure
        with pytest.raises(RetryAfter) as ei:
            t2.result(timeout=30)
        assert ei.value.reason == 'tenant_quota'
        assert ei.value.retry_after_ms > 0
        # the admitted request is NOT degraded: it completes normally
        np.testing.assert_allclose(t1.result(timeout=60),
                                   np.fft.fftn(x), atol=1e-3)
        m = c.metrics()
        assert m['tenants']['cap1']['rejected'] == {'tenant_quota': 1}


def test_service_retry_after_on_rate_and_window(mesh, sock_path):
    slos = {'hold': SLOClass('hold', deadline_ms=60000, max_wait_ms=800)}
    svc = FFTService(
        mesh, schedule_table=None, policy=None, watermark=10**6,
        max_inflight=1,                      # service-wide window of 1
        tenants=[TenantConfig('slow', rate_per_s=0.001, burst=1),
                 TenantConfig('other', max_inflight=4, slo='hold')],
        slo_classes={**slos, 'standard': SLOClass('standard', 250, 20)},
    ).start(sock_path)
    with svc:
        with svc.local_client('other') as co, \
                svc.local_client('slow') as cs:
            x = _creq((8, 8))
            held = co.submit(x, slo='hold')  # occupies the whole window
            with pytest.raises(RetryAfter) as ei:
                co.submit(x, slo='hold').result(timeout=30)
            assert ei.value.reason == 'inflight_window'
            # admission order is rate -> quota -> window: slow's first
            # request spends its only token but dies on the full
            # window; the second dies on the empty bucket (~no refill)
            with pytest.raises(RetryAfter) as ei1:
                cs.submit(x).result(timeout=30)
            assert ei1.value.reason == 'inflight_window'
            with pytest.raises(RetryAfter) as ei2:
                cs.submit(x).result(timeout=30)
            assert ei2.value.reason == 'rate'
            held.result(timeout=60)


def test_service_auth_and_unknown_tenants(mesh, sock_path):
    svc = FFTService(
        mesh, schedule_table=None,
        tenants=[TenantConfig('sec', token='s3cret')],
    ).start(sock_path)
    with svc:
        with pytest.raises(PermissionError, match="unknown tenant"):
            FFTClient(sock_path, tenant='nobody')
        with pytest.raises(PermissionError, match="token"):
            FFTClient(sock_path, tenant='sec', token='wrong')
        with FFTClient(sock_path, tenant='sec', token='s3cret') as c:
            assert c.server_info['tenant'] == 'sec'
            x = _creq((8, 8))
            np.testing.assert_allclose(c.transform([x])[0],
                                       np.fft.fftn(x), atol=1e-3)


def test_service_version_mismatch_answered_typed(mesh, sock_path):
    with FFTService(mesh, schedule_table=None).start(sock_path):
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.connect(sock_path)
        try:
            frame = bytearray(proto.pack_frame(proto.HELLO,
                                               {'tenant': 'v'}))
            frame[4] = proto.PROTOCOL_VERSION + 1
            s.sendall(bytes(frame))
            msg_type, meta, _ = proto.recv_frame(s)
            assert msg_type == proto.ERROR
            assert meta['kind'] == 'version'
            assert 'protocol v' in meta['error']
            assert proto.recv_frame(s) is None   # then the close
        finally:
            s.close()


def test_service_metrics_schema_and_slo_accounting(mesh, sock_path):
    svc = FFTService(mesh, schedule_table=None).start(sock_path)
    with svc, svc.local_client('m0') as c:
        c.transform([_creq((8, 8)) for _ in range(3)], slo='interactive')
        c.drain(timeout=60)
        m = c.metrics()
    assert set(m) == {'service', 'tenants', 'shapes'}
    s = m['service']
    assert s['inflight'] == 0 and s['max_inflight'] == 64
    assert 'queue_depths' in s and 'dispatch' in s
    assert sum(s['dispatch']['width_hist'].values()) == s['dispatch']['groups'] > 0
    assert s['policy'] is not None and s['policy']['watermark'] >= 1
    t = m['tenants']['m0']
    assert t['completed'] == 3 and t['failed'] == 0
    lat = t['latency_ms']['interactive']
    assert lat['count'] == 3
    assert 0 < lat['p50_ms'] <= lat['p99_ms']
    assert lat['slo_deadline_ms'] == 50.0
    assert isinstance(lat['violations'], int)
    assert m['shapes'] and all(v['count'] for v in m['shapes'].values())


def test_service_unknown_slo_is_request_error(mesh, sock_path):
    with FFTService(mesh, schedule_table=None).start(sock_path) as svc:
        with svc.local_client('t') as c:
            t = c.submit(_creq((8, 8)), slo='platinum')
            with pytest.raises(RuntimeError, match="unknown SLO"):
                t.result(timeout=30)


def test_service_graceful_drain_on_close(mesh, sock_path):
    # requests that sit in the coalescing queue (huge watermark, 800 ms
    # wait): close(drain=True) must serve them and FLUSH their result
    # frames before tearing the connections down
    slos = {'hold': SLOClass('hold', deadline_ms=60000, max_wait_ms=800)}
    svc = FFTService(mesh, schedule_table=None, policy=None,
                     watermark=10**6, slo_classes=slos,
                     tenants=[TenantConfig('d0', slo='hold')],
                     ).start(sock_path)
    c = svc.local_client('d0')
    tickets = [c.submit(_creq((8, 8))) for _ in range(4)]
    deadline = time.monotonic() + 30
    while svc._inflight_total < 4:           # all four admitted & held
        assert time.monotonic() < deadline
        time.sleep(0.005)
    svc.close(drain=True)                    # serves + flushes all 4
    assert svc._inflight_total == 0
    assert svc.engine.closed
    for t in tickets:
        assert t.result(timeout=30).shape == (8, 8)
    c.close()
    assert not os.path.exists(sock_path)     # socket path cleaned up
    svc.close()                              # idempotent


def test_service_adaptive_policy_retargets_engine(mesh, sock_path):
    svc = FFTService(mesh, schedule_table=None).start(sock_path)
    with svc, svc.local_client('load') as c:
        lo = svc._last_decision
        assert lo is not None and lo.watermark == 1     # idle: narrow
        # a burst of offered requests raises the load level and the
        # engine's watermark with it
        for _ in range(400):
            svc.policy.observe(4)
        svc._apply_policy()
        hi = svc._last_decision
        assert hi.load_level > lo.load_level
        assert hi.watermark > lo.watermark
        assert svc.engine.watermark == hi.watermark
        # decisions persist as load-tagged rows on close
        rows = svc.policy.rows(dict(svc.engine.mesh.shape), (8, 8),
                               'complex', 'auto')
        assert {r['load'] for r in rows} >= {lo.load_level, hi.load_level}
        c.transform([_creq((8, 8))])


def test_client_ticket_timeout_leaves_request_pending(mesh, sock_path):
    slos = {'hold': SLOClass('hold', deadline_ms=60000, max_wait_ms=700)}
    svc = FFTService(mesh, schedule_table=None, policy=None,
                     watermark=10**6, slo_classes=slos,
                     tenants=[TenantConfig('t', slo='hold')]).start(sock_path)
    with svc, svc.local_client('t') as c:
        x = _creq((8, 8))
        t = c.submit(x)
        with pytest.raises(ResultTimeout):
            t.result(timeout=0.05)           # still queued server-side
        np.testing.assert_allclose(t.result(timeout=60),
                                   np.fft.fftn(x), atol=1e-3)


# ---------------------------------------------------------------------------
# 16-device multi-tenant matrix (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_service_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_SERVE_SCHEDULES"] = ""        # deterministic picks
    proc = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_serve_service_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stdout[-4000:] + "\n" + proc.stderr[-4000:]
    assert "SERVE_SERVICE_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 5
