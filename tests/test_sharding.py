"""Sharding rules: divisibility guard and axis-collision guard.
Hypothesis property tests over arbitrary shapes live in
test_sharding_properties.py (skipped without hypothesis)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import make_rules, spec_for


@pytest.fixture(scope='module')
def mesh():
    return jax.make_mesh((1, 1), ('data', 'model'))


# rules bound to a *virtual* 16x16 mesh for pure spec logic (no devices)
class FakeMesh:
    shape = {'data': 16, 'model': 16}


def rules(mode='train'):
    from repro.parallel.sharding import Rules
    r = make_rules.__wrapped__ if hasattr(make_rules, '__wrapped__') else None
    # build the table against the fake mesh
    import repro.parallel.sharding as S
    table = {
        'batch': 'data', 'embed': 'data', 'heads': 'model',
        'kv_heads': 'model', 'mlp': 'model', 'vocab': 'model',
        'expert': 'model', 'seq': None, 'seq_sp': 'model',
        'kv_seq': 'model' if mode == 'serve' else None,
        'state': None, 'kv_lora': None, 'pos': None,
    }
    return S.Rules(table=table, mesh=FakeMesh())


def test_divisibility_guard_drops_axis():
    r = rules()
    # kv_heads = 8 does not divide model=16 -> replicated
    assert spec_for(r, (32, 128, 8, 64),
                    ('batch', None, 'kv_heads', None)) == P('data')
    # kv_heads = 32 divides -> sharded
    assert spec_for(r, (32, 128, 32, 64),
                    ('batch', None, 'kv_heads', None)) == \
        P('data', None, 'model')


def test_axis_collision_guard():
    r = rules()
    # two logical axes mapping to 'model': only the first is applied
    assert spec_for(r, (64, 160, 1024), ('heads', 'expert', None)) == \
        P('model')


def test_trailing_nones_trimmed():
    r = rules()
    s = spec_for(r, (4, 4), (None, None))
    assert s == P()


def test_serve_mode_kv_seq():
    r = rules('serve')
    assert spec_for(r, (128, 32768, 8, 128),
                    ('batch', 'kv_seq', 'kv_heads', None)) == \
        P('data', 'model')


