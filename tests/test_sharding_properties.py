"""Hypothesis property tests for the sharding rules and loss math
(optional dev dependency; skipped when hypothesis is not installed —
deterministic coverage lives in test_sharding.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.parallel import spec_for  # noqa: E402


# rules bound to a *virtual* 16x16 mesh for pure spec logic (no devices)
class FakeMesh:
    shape = {'data': 16, 'model': 16}


def rules(mode='train'):
    import repro.parallel.sharding as S
    table = {
        'batch': 'data', 'embed': 'data', 'heads': 'model',
        'kv_heads': 'model', 'mlp': 'model', 'vocab': 'model',
        'expert': 'model', 'seq': None, 'seq_sp': 'model',
        'kv_seq': 'model' if mode == 'serve' else None,
        'state': None, 'kv_lora': None, 'pos': None,
    }
    return S.Rules(table=table, mesh=FakeMesh())


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(
    st.integers(min_value=1, max_value=4096),
    st.sampled_from(['batch', 'embed', 'heads', 'kv_heads', 'mlp',
                     'vocab', 'expert', 'seq', 'kv_seq', None])),
    min_size=1, max_size=5))
def test_spec_always_valid(dims_axes):
    """Property: for ANY shape/axes combination the produced spec (a) only
    shards divisible dims, (b) never reuses a mesh axis."""
    r = rules('serve')
    shape = tuple(d for d, _ in dims_axes)
    axes = tuple(a for _, a in dims_axes)
    spec = spec_for(r, shape, axes)
    used = []
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        for p in parts:
            assert p not in used, f'axis {p} reused in {spec}'
            used.append(p)
        size = 1
        for p in parts:
            size *= FakeMesh.shape[p]
        assert dim % size == 0, f'dim {dim} not divisible by {size}'


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=6))
def test_xent_matches_manual(b, s, v):
    """Property: softmax_xent equals -log p[label] computed directly."""
    from repro.models.layers import softmax_xent
    key = jax.random.PRNGKey(b * 64 + s * 8 + v)
    logits = jax.random.normal(key, (b, s, v + 1), jnp.float32) * 3
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, v + 1)
    got = float(softmax_xent(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.mean(jnp.take_along_axis(p, labels[..., None],
                                               -1)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)