"""Fused spectral-operator plans (``fft.plan_op``) and the fftconv
mixer regressions that motivated them.

In-process tests run on a 1x1 mesh (same shard_map program, group size
1). The 16-fake-device matrix — fused vs unfused bitwise identity
across comm strategies, wire dtypes, kernel tiers and ranks, plus
engine serving — runs in a subprocess (_spectral_op_worker.py) so this
process keeps one device.
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.fft as fft
from repro.fft import methods as fftm
from repro.models import ssd

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("x", "y"))


def _pw_scale(re, im):
    return re * 2.0, im * 2.0


# -- plan_op construction and validation --------------------------------


def test_plan_op_validation(mesh):
    with pytest.raises(ValueError, match="op must be callable"):
        fft.plan_op((16, 32), mesh, op=42)
    with pytest.raises(ValueError, match="spectra_form"):
        fft.plan_op((16, 32), mesh, op=_pw_scale, spectra_form="nope")
    with pytest.raises(ValueError, match="n_spectra"):
        fft.plan_op((16, 32), mesh, op=_pw_scale, n_spectra=-1)
    with pytest.raises(ValueError, match="restore_layout"):
        fft.plan_op((16, 32), mesh, op=_pw_scale, restore_layout=True)
    with pytest.raises(ValueError, match="batch_spec"):
        fft.plan_op((16, 32), mesh, op=_pw_scale, batch_spec="x")


def test_plan_op_derives_padded_spectrum(mesh):
    # real rank>=2 operator plans ALWAYS keep the padded native
    # spectrum interior — the option is derived, never user-set
    op = fft.plan_op((16, 32), mesh, op=_pw_scale, real=True,
                     padded_spectrum=False)
    assert op.padded_spectrum
    op1 = fft.plan_op((256,), mesh, op=_pw_scale, real=True)
    assert not op1.padded_spectrum      # rank 1 has no pencil padding
    assert not op.restore_layout and op.batch_spec is None


def test_apply_operand_validation(mesh):
    op = fft.plan_op((16, 32), mesh, op=_pw_scale, real=True)
    x = jnp.asarray(RNG.standard_normal((16, 32)), jnp.float32)
    with pytest.raises(ValueError, match="runtime spectra"):
        op.apply(x, x)
    with pytest.raises(ValueError, match="real arrays"):
        op.apply(x.astype(jnp.complex64))
    with pytest.raises(ValueError, match="single real arrays"):
        op.apply((x, x))
    with pytest.raises(ValueError, match="does not end with"):
        op.apply(x[:, :16])


# -- fused == unfused on the 1x1 mesh -----------------------------------


@pytest.mark.parametrize("shape", [(256,), (16, 32), (8, 8, 8)])
def test_fused_matches_unfused_real(mesh, shape):
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    k = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                     n_spectra=1, donate=False)
    got = np.asarray(op.apply(x, k))
    axes = tuple(range(len(shape)))
    want = np.fft.irfftn(
        np.fft.rfftn(np.asarray(x, np.float64), axes=axes) *
        np.fft.rfftn(np.asarray(k, np.float64), axes=axes),
        s=shape, axes=axes)
    np.testing.assert_allclose(got, want, atol=3e-4 * np.max(np.abs(want)))
    # same shape/dtype round trip: the fused chain ends where it began
    assert got.shape == shape and got.dtype == np.float32


def test_fused_matches_unfused_complex(mesh):
    shape = (16, 32)
    x = RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)
    op = fft.plan_op(shape, mesh, op=_pw_scale, real=False, donate=False)
    got = np.asarray(op.apply(jnp.asarray(x, jnp.complex64)),
                     np.complex128)
    p = fft.plan(shape, mesh)
    want = np.asarray(p.inverse(p.forward(jnp.asarray(x, jnp.complex64))
                                * 2.0), np.complex128)
    np.testing.assert_allclose(got, want, atol=1e-5 * np.max(np.abs(want)))


def test_baked_spectrum_once(mesh):
    shape = (16, 32)
    k = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    op = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                     donate=False, spectra=(k,))
    assert op.bake_count == 0 and op.n_baked == 1 and op.n_spectra == 0
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    y0 = np.asarray(op.apply(x))
    for _ in range(3):
        assert np.array_equal(np.asarray(op.apply(x)), y0)
    assert op.bake_count == 1           # transformed once, ever
    rt = fft.plan_op(shape, mesh, op=fft.spectral_mul, real=True,
                     n_spectra=1, donate=False)
    assert np.array_equal(np.asarray(rt.apply(x, k)), y0)


def test_plan_cost_shows_elided_gather(mesh):
    op = fft.plan_op((1024,), mesh, op=_pw_scale, real=True)
    pc = op.plan_cost()
    kinds = [s.kind for s in pc.steps]
    assert "elided" in kinds and "pointwise" in kinds
    elided = [s for s in pc.steps if s.kind == "elided"]
    assert all(s.cycles == 0.0 for s in elided)
    assert "elided" in op.cost_report()


# -- with_options round-trip (satellite: the resolved-options contract) -


OPTION_MATRIX = [
    {"comm": "ppermute"},
    {"comm": "hierarchical"},
    {"overlap_chunks": 2},
    {"kernel": "reference"},
    {"wire_dtype": "fp16"},
    {"donate": False},
    # NOTE: compute_dtype=bf16 is untestable here — real plans hit
    # lax.complex on bf16 pencils (pre-existing, not op-plan specific)
    {"wire_dtype": "bf16"},
]


@pytest.mark.parametrize("ov", OPTION_MATRIX,
                         ids=[f"{k}={v}" for d in OPTION_MATRIX
                              for k, v in d.items()])
def test_with_options_roundtrips_op_plan(mesh, ov):
    k = jnp.asarray(RNG.standard_normal((16, 32)), jnp.float32)
    op = fft.plan_op((16, 32), mesh, op=fft.spectral_mul, real=True,
                     donate=True, spectra=(k,), op_name="conv")
    op2 = op.with_options(**ov)
    assert isinstance(op2, fft.SpectralOp)
    # the op-specific options survive the re-plan...
    assert op2.op is fft.spectral_mul and op2.op_name == "conv"
    assert op2.n_spectra == 0 and op2.n_baked == 1
    assert op2.spectra_form == "plan"
    assert op2.padded_spectrum and not op2.restore_layout
    # ...the override landed...
    for key, val in ov.items():
        assert getattr(op2, key) == val, key
    # ...and everything else carried over resolved
    base = op._options()
    for key, val in op2._options().items():
        if key not in ov and key not in ("spectra",):
            assert val == base[key], key
    xv = RNG.standard_normal((16, 32))
    if ov.get("wire_dtype") == "fp16":
        tol = 5e-3
    elif ov.get("wire_dtype") == "bf16":
        tol = 3e-2
    else:
        tol = 1e-5
    # donating plans consume their operand — fresh array per apply
    a = np.asarray(op.apply(jnp.asarray(xv, jnp.float32)))
    b = np.asarray(op2.apply(jnp.asarray(xv, jnp.float32)))
    np.testing.assert_allclose(b, a, atol=tol * max(np.max(np.abs(a)), 1))
    assert op2.bake_count == 1          # fresh plan baked its own copy


def test_with_options_roundtrips_real_padded_plan(mesh):
    # plain (non-op) real padded_spectrum plans keep the padding knob
    rp = fft.rplan((16, 32), mesh, padded_spectrum=True)
    for ov in ({"comm": "ppermute"}, {"overlap_chunks": 2},
               {"donate": False}):
        rp2 = rp.with_options(**ov)
        assert rp2.real and rp2.padded_spectrum
        assert rp2.spectrum_shape == rp.spectrum_shape


# -- the fftconv mixer regressions --------------------------------------


def _old_fftconv_apply(p, cfg, x):
    """The pre-fix mixer, inlined verbatim: complex transforms built
    from real inputs via a zero imaginary plane, kernel FFT recomputed
    every forward. The new path must match it numerically."""
    import repro.models.layers as L
    B, S, d = x.shape
    h = L.apply_linear(p['wi'], x)
    klen = min(cfg.fftconv_len, S)
    decay = jnp.exp(-jax.nn.softplus(p['decay'].astype(jnp.float32))
                    * jnp.arange(klen, dtype=jnp.float32)[:, None])
    ker = p['kernel'].astype(jnp.float32)[:klen] * decay
    n = 2 * S
    hf = h.astype(jnp.float32).swapaxes(1, 2)
    kf = ker.T
    hr = jnp.pad(hf, ((0, 0), (0, 0), (0, n - S)))
    kr = jnp.pad(kf, ((0, 0), (0, n - klen)))
    hre, him = fftm.apply(hr, jnp.zeros_like(hr), method='four_step')
    kre, kim = fftm.apply(kr, jnp.zeros_like(kr), method='four_step')
    yre = hre * kre - him * kim
    yim = hre * kim + him * kre
    yr, _ = fftm.apply(yre, yim, inverse=True, method='four_step')
    y = yr[..., :S].swapaxes(1, 2).astype(x.dtype)
    return L.apply_linear(p['wo'], y)


def _fftconv_fixture(S=32, d=8, B=2):
    cfg = types.SimpleNamespace(fftconv_len=S)
    p = {
        'wi': {'w': jnp.asarray(RNG.standard_normal((d, d)) / np.sqrt(d),
                                jnp.float32)},
        'kernel': jnp.asarray(RNG.standard_normal((S, d)) * 0.1,
                              jnp.float32),
        'decay': jnp.asarray(RNG.standard_normal(d) * 0.3, jnp.float32),
        'wo': {'w': jnp.asarray(RNG.standard_normal((d, d)) / np.sqrt(d),
                                jnp.float32)},
    }
    x = jnp.asarray(RNG.standard_normal((B, S, d)), jnp.float32)
    return cfg, p, x


def test_fftconv_new_matches_old_fp32(mesh):
    cfg, p, x = _fftconv_fixture()
    old = np.asarray(_old_fftconv_apply(p, cfg, x))
    new = np.asarray(ssd.fftconv_apply(p, cfg, x, mesh=mesh))
    np.testing.assert_allclose(new, old,
                               atol=1e-5 * max(np.max(np.abs(old)), 1))
    local = np.asarray(ssd.fftconv_apply(p, cfg, x))   # mesh=None path
    np.testing.assert_allclose(local, old,
                               atol=1e-5 * max(np.max(np.abs(old)), 1))


def test_fftconv_kernel_fft_baked_once(mesh):
    cfg, p, x = _fftconv_fixture()
    y0 = np.asarray(ssd.fftconv_apply(p, cfg, x, mesh=mesh))
    key = ('baked', 2 * x.shape[1], mesh)
    tok, _refs, plan = ssd._fftconv_plans[key]
    assert plan.bake_count == 1
    for _ in range(3):    # repeated eval: same plan, no rebake
        assert np.array_equal(
            np.asarray(ssd.fftconv_apply(p, cfg, x, mesh=mesh)), y0)
    assert ssd._fftconv_plans[key][2] is plan and plan.bake_count == 1
    # new params -> new token -> fresh bake, exactly once
    p2 = dict(p, kernel=p['kernel'] * 0.5)
    ssd.fftconv_apply(p2, cfg, x, mesh=mesh)
    plan2 = ssd._fftconv_plans[key][2]
    assert plan2 is not plan and plan2.bake_count == 1


def test_fftconv_traced_path_inside_jit(mesh):
    cfg, p, x = _fftconv_fixture()
    eager = np.asarray(ssd.fftconv_apply(p, cfg, x, mesh=mesh))
    jitted = np.asarray(jax.jit(
        lambda pp, xx: ssd.fftconv_apply(pp, cfg, xx, mesh=mesh))(p, x))
    np.testing.assert_allclose(jitted, eager,
                               atol=1e-5 * max(np.max(np.abs(eager)), 1))
    assert ('rt', 2 * x.shape[1], mesh) in ssd._fftconv_plans


def test_fftconv_hermitian_imag_residual(mesh):
    # the real machinery's inverse is exactly real by construction;
    # cross-check: the complex-transform composition of the same conv
    # has ~zero imaginary residual, and its real part matches the
    # fused real path
    cfg, p, x = _fftconv_fixture()
    S, d = x.shape[1], x.shape[2]
    n = 2 * S
    import repro.models.layers as L
    h = L.apply_linear(p['wi'], x)
    klen = min(cfg.fftconv_len, S)
    decay = jnp.exp(-jax.nn.softplus(p['decay'].astype(jnp.float32))
                    * jnp.arange(klen, dtype=jnp.float32)[:, None])
    ker = p['kernel'].astype(jnp.float32)[:klen] * decay
    hr = jnp.pad(h.astype(jnp.float32).swapaxes(1, 2),
                 ((0, 0), (0, 0), (0, n - S)))
    kr = jnp.pad(ker.T, ((0, 0), (0, n - klen)))
    hre, him = fftm.apply(hr, jnp.zeros_like(hr), method='four_step')
    kre, kim = fftm.apply(kr, jnp.zeros_like(kr), method='four_step')
    yre, yim = fft.spectral_mul(hre, him, (kre, kim))
    yr, yi = fftm.apply(yre, yim, inverse=True, method='four_step')
    scale = max(float(jnp.max(jnp.abs(yr))), 1e-9)
    assert float(jnp.max(jnp.abs(yi))) / scale < 1e-5
    rre, rim = fftm.apply_real(hr, method='four_step')
    krr, kri = fftm.apply_real(kr, method='four_step')
    zre, zim = fft.spectral_mul(rre, rim, (krr, kri))
    zr = fftm.apply_real(zre, zim, inverse=True, method='four_step')
    np.testing.assert_allclose(np.asarray(zr), np.asarray(yr),
                               atol=1e-5 * scale)


def test_fftconv_lm_loss_parity(monkeypatch):
    # the fftconv_lm smoke with the OLD mixer vs the NEW fused-plan
    # mixer: loss curves must track (the fix changes execution, not
    # math)
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models import model as M
    from repro.train.optim import adamw_init
    from repro.train.trainstep import make_train_step

    cfg = dataclasses.replace(
        smoke_config(get_config('mamba2-1.3b')),
        block_pattern=('fftconv',), num_layers=2, d_model=16,
        vocab_size=64, fftconv_len=16)
    lm_mesh = jax.make_mesh((1, 1), ('data', 'model'))
    new_mixer = ssd.fftconv_apply

    def batches():
        rng = np.random.default_rng(7)
        for _ in range(6):
            toks = rng.integers(1, cfg.vocab_size, (2, 17)).astype(np.int32)
            yield {'tokens': jnp.asarray(toks[:, :-1]),
                   'labels': jnp.asarray(toks[:, 1:])}

    def run(mixer):
        monkeypatch.setattr(ssd, 'fftconv_apply', mixer)
        step = jax.jit(make_train_step(cfg, lm_mesh, peak_lr=3e-3,
                                       warmup_steps=2, total_steps=6,
                                       param_dtype=jnp.float32))
        params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        opt = adamw_init(params)
        losses = []
        for batch in batches():
            params, opt, m = step(params, opt, batch)
            losses.append(float(m['ce']))
        return losses

    new = run(new_mixer)
    old = run(lambda p, c, x, mesh=None: _old_fftconv_apply(p, c, x))
    np.testing.assert_allclose(new, old, rtol=2e-3, atol=2e-3)


def test_fftconv_gradients_flow(mesh):
    cfg, p, x = _fftconv_fixture()

    def loss(pp):
        return jnp.sum(ssd.fftconv_apply(pp, cfg, x, mesh=mesh) ** 2)

    g = jax.grad(loss)(p)
    for name in ('kernel', 'decay'):
        ga = np.asarray(g[name])
        assert np.all(np.isfinite(ga)) and np.max(np.abs(ga)) > 0, name


# -- engine integration (1x1 mesh; the 16-device flow is in the worker) -


def test_engine_register_and_serve_op(mesh):
    from repro.serve.fft_engine import FFTEngine
    shape = (16, 32)
    eng = FFTEngine(shape, mesh)
    k = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    op = eng.register_op("conv", shape=shape, op=fft.spectral_mul,
                         real=True, donate=False, spectra=(k,))
    assert "conv" in eng.registered_ops()
    xv = RNG.standard_normal(shape)
    # the engine re-plans with its own donate policy and consumes the
    # request buffer — take the direct-apply reference first
    want = np.asarray(op.apply(jnp.asarray(xv, jnp.float32)))
    t = eng.submit(jnp.asarray(xv, jnp.float32), op="conv")
    eng.flush()
    assert np.array_equal(np.asarray(t.result()), want)
    with pytest.raises(ValueError, match="direction"):
        eng.submit(jnp.asarray(xv, jnp.float32), op="conv",
                   direction="inv")
    with pytest.raises(ValueError, match="runtime spectra"):
        eng.register_op("bad", shape=shape, op=fft.spectral_mul,
                        real=True, n_spectra=1)
    eng.close()


# -- the 16-fake-device matrix ------------------------------------------


@pytest.mark.slow
def test_spectral_op_worker_16_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests",
                                      "_spectral_op_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, (
        f"worker failed\nstdout:\n{proc.stdout[-4000:]}\n"
        f"stderr:\n{proc.stderr[-4000:]}")
    assert "SPECTRAL_OP_WORKER_OK" in proc.stdout
    assert proc.stdout.count("PASS") >= 25, proc.stdout
