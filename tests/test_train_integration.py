"""End-to-end training integration: loss decreases, checkpoint/restart
resumes bit-compatibly, straggler monitor trips on injected delay."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLM
from repro.models import model as M
from repro.runtime import FailureInjector, StragglerMonitor, TrainDriver
from repro.train.optim import adamw_init
from repro.train.trainstep import make_train_step


def _setup(arch='internlm2-1.8b', B=4, S=32, lr=3e-3):
    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ('data', 'model'))
    step = make_train_step(cfg, mesh, peak_lr=lr, warmup_steps=5,
                           total_steps=60, param_dtype=jnp.float32)
    step = jax.jit(step, donate_argnums=(0, 1))
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw_init(params)
    data = SyntheticLM(cfg.vocab_size, S, B, seed=4)
    return cfg, step, params, opt, data


def test_loss_decreases():
    """The Markov-permutation stream is bigram-learnable: a tiny untied
    model must drop >1 nat below its start and below uniform in ~100
    steps."""
    cfg, step, params, opt, data = _setup('codeqwen1.5-7b', B=8, lr=1e-2)
    losses = []
    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m['ce']))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 1.0, (first, last)
    assert last < np.log(cfg.vocab_size) - 1.0


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Train 20 steps with a failure at step 13; the restarted run must
    end with exactly the same parameters as an uninterrupted run
    (deterministic data + deterministic optimizer)."""
    def run(ckpt_dir, fail_at):
        cfg, step, params, opt, data = _setup()
        driver = TrainDriver(
            step, ckpt_dir, ckpt_every=5, async_ckpt=False,
            injector=FailureInjector([fail_at] if fail_at else []))
        def batches(i):
            return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, end = driver.run(params, opt, batches, steps=20)
        return params, driver

    p_ref, d_ref = run(str(tmp_path / 'ref'), None)
    p_ft, d_ft = run(str(tmp_path / 'ft'), 13)
    assert d_ref.restarts == 0
    assert d_ft.restarts == 1
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_ft)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=0, rtol=0)


def test_straggler_monitor_trips():
    mon = StragglerMonitor(alpha=0.5, trip_factor=2.0, warmup=2)
    trips = []
    mon.on_trip = lambda s, dt, e: trips.append(s)
    for s, dt in enumerate([0.1, 0.1, 0.1, 0.1, 0.5, 0.1]):
        mon.observe(s, dt)
    assert trips == [4]
    assert mon.trips == 1
    # EWMA not poisoned by the straggler step
    assert mon.ewma < 0.15


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written under one sharding restores under another
    (the elastic re-mesh path) with identical values."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    mesh1 = jax.make_mesh((1, 1), ('data', 'model'))
    t = {'w': jax.device_put(
        jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        NamedSharding(mesh1, P('data', None)))}
    save_checkpoint(str(tmp_path), 1, t)
    mesh2 = jax.make_mesh((1, 1), ('a', 'b'))      # a "different fleet"
    sh = {'w': NamedSharding(mesh2, P(None, 'b'))}
    r = restore_checkpoint(str(tmp_path), 1, t, sh)
    np.testing.assert_array_equal(np.asarray(r['w']), np.asarray(t['w']))
    assert r['w'].sharding.spec == P(None, 'b')
