"""Half-precision wire formats and cost-searched pod-tree trees.

Fast, single-device: tree-spec parsing/canonicalization, the bounded
factorization enumeration and its cost-dominance guarantee over the
fixed two-phase split (deterministic sweeps plus hypothesis variants
when available), the wire-format helpers, the plan facade's option
round trip through ``FFT.with_options`` (regression: every resolved
comm/dtype option must survive a re-plan), and the serving schedule
table's wire tag. The 16-fake-device fp16/bf16 accuracy gate runs in
a subprocess (see _wire_accuracy_worker.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro import comm
from repro.comm import cost as ccost
from repro.comm import strategies as strat
from repro.core import wse_model as wm
from repro.core.plan import PencilPlan

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _abstract_mesh(*sizes, names=('x', 'y')):
    sharding = pytest.importorskip("jax.sharding")
    if not hasattr(sharding, 'AbstractMesh'):
        pytest.skip("jax.sharding.AbstractMesh unavailable")
    return sharding.AbstractMesh(tuple(zip(names, sizes)))


# ---------------------------------------------------------------------------
# tree-spec parsing / canonical naming
# ---------------------------------------------------------------------------

def test_parse_format_tree_spec_roundtrip():
    tree = strat.parse_tree_spec('x.4*y.2*y.2')
    assert tree == {'x': (4,), 'y': (2, 2)}
    assert strat.format_tree_spec(tree) == 'x.4*y.2*y.2'
    # axis order in the spec does not matter; the format is canonical
    assert (strat.format_tree_spec(strat.parse_tree_spec('y.2*x.4*y.2'))
            == 'x.4*y.2*y.2')


@pytest.mark.parametrize('bad', ['', 'x', 'x.1', 'x.0', 'x.-2', 'x.a',
                                 'x.2*', '.4'])
def test_parse_tree_spec_rejects(bad):
    with pytest.raises(ValueError):
        strat.parse_tree_spec(bad)


def test_validate_canonicalizes_pod_tree_names():
    assert (comm.validate('pod_tree:y.2*x.4*y.2')
            == 'pod_tree:x.4*y.2*y.2')
    # registered names and 'auto' pass through unchanged
    assert comm.validate('hierarchical') == 'hierarchical'
    assert comm.validate('auto') == 'auto'
    with pytest.raises(ValueError):
        comm.validate('pod_tree:nope')
    with pytest.raises(ValueError):
        comm.validate('no_such_strategy')


def test_pod_tree_strategies_share_one_instance():
    a = comm.get('pod_tree:x.4*y.2*y.2')
    b = comm.get('pod_tree:y.2*x.4*y.2')    # same tree, scrambled spec
    assert a.name == b.name == 'pod_tree:x.4*y.2*y.2'
    assert a.tree == b.tree == {'x': (4,), 'y': (2, 2)}


# ---------------------------------------------------------------------------
# wire-format helpers
# ---------------------------------------------------------------------------

def test_validate_wire_dtype():
    for wd in strat.WIRE_DTYPES:
        assert strat.validate_wire_dtype(wd) == wd
    with pytest.raises(ValueError):
        strat.validate_wire_dtype('fp8')


def test_wire_elem_bytes():
    assert strat.wire_elem_bytes('native', 4) == 4
    assert strat.wire_elem_bytes('native', 8) == 8
    assert strat.wire_elem_bytes('fp16', 4) == 2
    assert strat.wire_elem_bytes('bf16', 8) == 2
    # a compact wire never *widens* an already-narrow component
    assert strat.wire_elem_bytes('fp16', 2) == 2


def test_wire_cast_restore_semantics():
    x = jnp.arange(8, dtype=jnp.float32)
    w, restore = strat.wire_cast(x, 'fp16')
    assert w.dtype == jnp.float16 and restore == jnp.float32
    assert strat.wire_restore(w, restore).dtype == jnp.float32
    # native: no cast, nothing to restore
    w, restore = strat.wire_cast(x, 'native')
    assert w is x and restore is None
    assert strat.wire_restore(w, restore) is w
    # operands already at (or below) wire width pass through untouched
    nar = jnp.arange(8, dtype=jnp.bfloat16)
    w, restore = strat.wire_cast(nar, 'fp16')
    assert w is nar and restore is None
    # non-float operands (index/bool payloads) are never cast
    ints = jnp.arange(8, dtype=jnp.int32)
    w, restore = strat.wire_cast(ints, 'fp16')
    assert w is ints and restore is None


def test_pencil_plan_rejects_unknown_wire_dtype():
    mesh = _abstract_mesh(4, 4)
    p = PencilPlan(shape=(32, 32, 32), mesh=mesh, layout=('x', 'y', None),
                   wire_dtype='fp8')
    with pytest.raises(ValueError, match='wire_dtype'):
        p.validate()


# ---------------------------------------------------------------------------
# factorization enumeration (the pod-tree search space)
# ---------------------------------------------------------------------------

def _check_factorizations(extent, depth):
    seqs = ccost.enumerate_axis_factorizations(extent, depth)
    assert len(set(seqs)) == len(seqs)
    for fs in seqs:
        assert 1 <= len(fs) <= depth or (extent == 1 and fs == ())
        prod = 1
        for f in fs:
            assert f >= 2
            prod *= f
        assert prod == extent
    if extent > 1:
        # the single-level (full all_to_all) split always leads
        assert seqs[0] == (extent,)


@pytest.mark.parametrize('extent', [1, 2, 4, 8, 16, 32, 64, 256])
@pytest.mark.parametrize('depth', [1, 2, 3, 4])
def test_enumerate_axis_factorizations_properties(extent, depth):
    _check_factorizations(extent, depth)


def test_enumerate_trees_properties():
    for mesh_shape in ({'x': 4, 'y': 4}, {'x': 8, 'y': 2},
                       {'x': 16, 'y': 1}, {'x': 2, 'y': 2}):
        names = ccost.enumerate_trees(tuple(mesh_shape), mesh_shape)
        assert 0 < len(names) <= ccost.POD_TREE_MAX_TREES
        assert len(set(names)) == len(names)
        for name in names:
            assert name.startswith(strat.POD_TREE_PREFIX)
            tree = strat.parse_tree_spec(name[len(strat.POD_TREE_PREFIX):])
            for a, fs in tree.items():
                assert len(fs) <= ccost.POD_TREE_MAX_DEPTH
                assert np.prod(fs) == mesh_shape[a]
            # extent-1 axes never appear in a spec
            assert all(mesh_shape[a] > 1 for a in tree)
        # the first candidate is the all-full tree: one level per
        # (non-trivial) axis, i.e. exactly the fixed two-phase split —
        # the search minimum can therefore never beat it by less than 0
        full = {a: (e,) for a, e in mesh_shape.items() if e > 1}
        assert names[0] == strat.POD_TREE_PREFIX + strat.format_tree_spec(
            full)


def test_tree_search_never_worse_than_two_phase():
    """The analytic search minimum is <= the fixed two-phase split's
    cost: 'hierarchical' prices as the all-full two-level tree, which
    is always in the candidate set."""
    for shape, layout, mesh_shape in (
            ((32, 32, 32), ('x', 'y', None), {'x': 4, 'y': 4}),
            ((64, 64, 64), ('x', 'y', None), {'x': 8, 'y': 8}),
            ((512, 512, 512), ('x', 'y', None), {'x': 512, 'y': 512})):
        sel = ccost.select(shape, layout, mesh_shape, measured=None,
                           pod_trees=True)
        hier = sel.costs['hierarchical'].cycles
        assert sel.costs[sel.strategy].cycles <= hier + 1e-9, (
            shape, mesh_shape, sel.strategy)


def test_tree_candidates_policy():
    mesh_shape = {'x': 4, 'y': 4}
    assert ccost._tree_candidates(mesh_shape, None, False) == ()
    full = ccost._tree_candidates(mesh_shape, None, True)
    assert full and all(n.startswith(strat.POD_TREE_PREFIX) for n in full)
    # default: only trees the measured table has rows for on this mesh
    tbl = ccost.MeasuredTable([
        {'mesh': '4x4', 'group': 'x*y', 'strategy': 'pod_tree:x.2*x.2*y.4',
         'local_elems': 1024, 'us': 10.0},
        {'mesh': '4x4', 'group': 'x*y', 'strategy': 'all_to_all',
         'local_elems': 1024, 'us': 12.0},
    ])
    got = ccost._tree_candidates(mesh_shape, tbl, None)
    assert got == ('pod_tree:x.2*x.2*y.4',)
    assert ccost._tree_candidates({'x': 8, 'y': 2}, tbl, None) == ()


# hypothesis variants ------------------------------------------------------

def test_factorization_properties_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=80)
    @hyp.given(k=st.integers(0, 10), depth=st.integers(1, 4))
    def run(k, depth):
        _check_factorizations(2 ** k, depth)

    run()


def test_tree_cost_dominance_hypothesis():
    """Min modeled swap cost over the enumerated trees of a mesh axis
    group never exceeds the two-phase hierarchical split's."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=60)
    @hyp.given(kx=st.integers(1, 6), ky=st.integers(1, 6),
               loge=st.integers(6, 20))
    def run(kx, ky, loge):
        mesh_shape = {'x': 2 ** kx, 'y': 2 ** ky}
        elems = float(2 ** loge)
        hier = comm.get('hierarchical').cost(
            ('x', 'y'), mesh_shape, elems, 'fp32').cycles
        best = min(
            comm.get(name).cost(('x', 'y'), mesh_shape, elems,
                                'fp32').cycles
            for name in ccost.enumerate_trees(('x', 'y'), mesh_shape))
        assert best <= hier + 1e-9

    run()


# ---------------------------------------------------------------------------
# cost model: trees and wire formats
# ---------------------------------------------------------------------------

def test_swap_cycles_tree_generalizes_hierarchical():
    for p1, p2, elems in ((4, 4, 2048), (8, 8, 65536), (512, 512, 2 ** 18)):
        levels = ((p1, 'a2a', 1.0), (p2, 'a2a', 1.0))
        assert (wm.swap_cycles_tree(levels, elems, 'fp32')
                == wm.swap_cycles_hierarchical(p1, p2, elems, 'fp32'))
    # a single full level prices as plain a2a plus no reorder term
    one = wm.swap_cycles_tree(((16, 'a2a', 1.0),), 4096, 'fp32')
    assert one == wm.swap_cycles_a2a(16, 4096, 'fp32')


def test_wire_dtype_halves_analytic_wire_term():
    """fp16 wire prices every swap's wire term at r=1 (the paper packs
    an fp16 (re, im) pair in one 32-bit wavelet) — the analytic cost
    must strictly drop vs fp32 native wire."""
    pc32 = ccost.pencil_plan_cost((32, 32, 32), ('x', 'y', None),
                                  {'x': 4, 'y': 4}, measured=None)
    pc16 = ccost.pencil_plan_cost((32, 32, 32), ('x', 'y', None),
                                  {'x': 4, 'y': 4}, measured=None,
                                  wire_dtype='fp16')
    assert pc16.wire_dtype == 'fp16'
    sw32 = [s for s in pc32.steps if s.kind == 'swap']
    sw16 = [s for s in pc16.steps if s.kind == 'swap']
    assert len(sw32) == len(sw16)
    for a, b in zip(sw32, sw16):
        assert b.swap.wire_cycles < a.swap.wire_cycles
        assert 'wire=fp16' in b.detail


def test_cost_report_shows_tree_and_wire_bytes():
    mesh = _abstract_mesh(4, 4)
    import repro.fft as fft
    p = fft.plan((32, 32, 32), mesh, comm='pod_tree:x.2*x.2*y.4',
                 wire_dtype='fp16')
    rep = p.cost_report()
    assert 'wire_dtype=fp16' in rep
    assert 'pod tree: x: 4 -> 2x2  y: 4 -> 4' in rep
    assert 'KiB/dev wire' in rep
    # per-superstep wire bytes: 32^3/16 elems/dev, 2 components x 2 B
    assert '8.0 KiB/dev wire' in rep


def test_schedule_table_wire_tag():
    mk = dict(mesh='4x4', shape='32x32x32', kind='complex',
              strategy='all_to_all', coalesce_width=8, overlap_chunks=2,
              us_per_request=10.0)
    wired = dict(mk, wire='fp16', coalesce_width=16, us_per_request=8.0)
    tbl = ccost.ScheduleTable([mk, wired])
    assert len(tbl) == 2            # distinct keys, no clobbering
    ms = {'x': 4, 'y': 4}
    nat = tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all')
    assert nat is not None and nat['coalesce_width'] == 8
    f16 = tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                     wire='fp16')
    assert f16 is not None and f16['coalesce_width'] == 16
    # a bf16 lookup has no measured row — no silent cross-wire answers
    assert tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                      wire='bf16') is None


# ---------------------------------------------------------------------------
# facade: option round trip (regression) and wire selection
# ---------------------------------------------------------------------------

def test_with_options_roundtrips_comm_and_dtype_options():
    """Regression: every resolved non-default option — strategy
    (including parameterized pod trees), wire format, compute dtype,
    method, overlap depth — must survive ``with_options`` re-plans."""
    import repro.fft as fft
    mesh = _abstract_mesh(4, 4)
    p = fft.plan((32, 32, 32), mesh, comm='pod_tree:x.4*y.2*y.2',
                 wire_dtype='fp16', compute_dtype=jnp.bfloat16,
                 method='stockham', overlap_chunks=2)
    q = p.with_options(donate=False)
    assert q.comm == p.comm == 'pod_tree:x.4*y.2*y.2'
    assert q.wire_dtype == 'fp16'
    assert q.compute_dtype == jnp.bfloat16
    assert q.method == 'stockham'
    assert q.overlap_chunks == 2
    assert q.donate is False
    # the override wins without disturbing its neighbors
    r = q.with_options(wire_dtype='bf16')
    assert r.wire_dtype == 'bf16' and r.comm == p.comm
    # the executor plan carries the wire format too
    assert p._pplan.wire_dtype == 'fp16'
    # rank-1 plans round-trip the same set
    p1 = fft.plan((4096,), mesh, comm='hierarchical', wire_dtype='bf16',
                  compute_dtype=jnp.bfloat16)
    q1 = p1.with_options(overlap_chunks=4)
    assert (q1.comm, q1.wire_dtype, q1.compute_dtype,
            q1.overlap_chunks) == ('hierarchical', 'bf16', jnp.bfloat16, 4)
    # real <-> complex re-plans keep the wire format as well
    pr = fft.rplan((32, 32, 32), mesh, comm='hierarchical',
                   wire_dtype='fp16')
    qc = pr.with_options(real=False)
    assert qc.wire_dtype == 'fp16' and qc.comm == 'hierarchical'


def test_plan_rejects_unknown_wire_dtype():
    import repro.fft as fft
    mesh = _abstract_mesh(4, 4)
    with pytest.raises(ValueError, match='wire_dtype'):
        fft.plan((32, 32, 32), mesh, wire_dtype='fp8')


# ---------------------------------------------------------------------------
# kernel tier: plan option, deprecated alias, schedule-table tag
# ---------------------------------------------------------------------------

def test_with_options_roundtrips_kernel_tier():
    """Regression: the kernel tier survives ``with_options`` re-plans,
    like comm/wire/compute-dtype (same contract, same test shape)."""
    import repro.fft as fft
    mesh = _abstract_mesh(4, 4)
    p = fft.plan((32, 32, 32), mesh, method='stockham', kernel='pallas')
    q = p.with_options(donate=False)
    assert q.kernel == p.kernel == 'pallas'
    assert q.resolved_kernel == 'pallas'
    r = q.with_options(kernel='reference')
    assert r.kernel == 'reference' and r.comm == p.comm
    assert 'pallas' in repr(p)
    # rank-1 and real plans carry the tier too
    p1 = fft.plan((4096,), mesh, kernel='pallas')
    assert p1.with_options(overlap_chunks=4).kernel == 'pallas'
    pr = fft.rplan((32, 32, 32), mesh, kernel='pallas')
    assert pr.with_options(real=False).kernel == 'pallas'
    # 'auto' resolves to 'reference' on this CPU host
    pa = fft.plan((32, 32, 32), mesh)
    assert pa.kernel == 'auto' and pa.resolved_kernel == 'reference'


def test_plan_rejects_unknown_kernel_tier():
    import repro.fft as fft
    mesh = _abstract_mesh(4, 4)
    with pytest.raises(ValueError, match='kernel'):
        fft.plan((32, 32, 32), mesh, kernel='mosaic')


def test_use_kernel_deprecated_alias_warns_once_and_maps():
    import repro.fft as fft
    from repro.core import _deprecated
    mesh = _abstract_mesh(4, 4)
    _deprecated.reset('repro.fft.plan(use_kernel=)')
    with pytest.warns(DeprecationWarning, match="kernel='pallas'"):
        p = fft.plan((32, 32, 32), mesh, use_kernel=True)
    assert p.kernel == 'pallas'
    # one-shot: a second deprecated call stays silent
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter('error')
        p2 = fft.plan((32, 32, 32), mesh, use_kernel=True)
    assert p2.kernel == 'pallas'
    # use_kernel=False is inert: the kernel option passes through
    p3 = fft.plan((32, 32, 32), mesh, kernel='reference', use_kernel=False)
    assert p3.kernel == 'reference'


def test_cost_report_shows_kernel_tier():
    import repro.fft as fft
    mesh = _abstract_mesh(4, 4)
    rep = fft.plan((32, 32, 32), mesh, method='stockham',
                   kernel='pallas').cost_report()
    assert 'kernel=pallas' in rep
    assert '(stockham/pallas)' in rep
    rep_ref = fft.plan((32, 32, 32), mesh, method='stockham').cost_report()
    assert 'kernel=reference' in rep_ref
    assert '(stockham/reference)' in rep_ref


def test_schedule_table_kernel_tag():
    """Kernel-tagged autotune rows answer only same-tier lookups —
    mirrors the wire-tag contract."""
    mk = dict(mesh='4x4', shape='32x32x32', kind='complex',
              strategy='all_to_all', coalesce_width=8, overlap_chunks=2,
              us_per_request=10.0)
    tiered = dict(mk, kernel='pallas', coalesce_width=4,
                  us_per_request=9.0)
    tbl = ccost.ScheduleTable([mk, tiered])
    assert len(tbl) == 2            # distinct keys, no clobbering
    ms = {'x': 4, 'y': 4}
    ref = tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all')
    assert ref is not None and ref['coalesce_width'] == 8
    pal = tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                     kernel='pallas')
    assert pal is not None and pal['coalesce_width'] == 4
    # no measured row for an unknown tier — no silent cross-tier answers
    assert tbl.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                      kernel='mosaic') is None
    # wire and kernel tags compose into one key space
    both = dict(mk, wire='fp16', kernel='pallas', coalesce_width=16)
    tbl2 = ccost.ScheduleTable([mk, tiered, both])
    assert len(tbl2) == 3
    hit = tbl2.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                      wire='fp16', kernel='pallas')
    assert hit is not None and hit['coalesce_width'] == 16
    assert tbl2.lookup(ms, (32, 32, 32), 'complex', 'all_to_all',
                       wire='fp16') is None


def test_auto_select_with_measured_tree_prefers_it():
    """select(): a pod tree with (much faster) measured rows on this
    mesh wins comm='auto'; without measured rows no tree is even
    considered (paper-faithful default ranking)."""
    mesh_shape = {'x': 4, 'y': 4}
    tree = 'pod_tree:x.4*y.2*y.2'
    rows = [{'mesh': '4x4', 'group': g, 'strategy': s,
             'local_elems': e, 'us': us}
            for g in ('x', 'y', 'x*y')
            for e in (256, 8192)
            for s, us in ((tree, 1.0), ('all_to_all', 50.0))]
    tbl = ccost.MeasuredTable(rows)
    sel = ccost.select((32, 32, 32), ('x', 'y', None), mesh_shape,
                       measured=tbl)
    assert sel.strategy == tree
    sel_none = ccost.select((32, 32, 32), ('x', 'y', None), mesh_shape,
                            measured=None)
    assert not sel_none.strategy.startswith(strat.POD_TREE_PREFIX)


# ---------------------------------------------------------------------------
# 16-device accuracy gate (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_wire_accuracy_worker_16_devices():
    """fp16/bf16 wire error bounds vs the fp32 native-wire reference,
    and native-wire bit-identity, for ranks 1/2/3 across strategies
    and pod trees — on 16 fake devices."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_wire_accuracy_worker.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "WIRE_WORKER_OK" in proc.stdout
