"""Faithful-reproduction checks: our implementation of the paper's
performance model (Eqs. 1-12) against the paper's own published claims."""
import math

import pytest

from repro.core import wse_model as wm


def test_headline_959us():
    """§9: 959 microseconds for the 512^3 FP32 FFT."""
    assert abs(wm.runtime_us(wm.TABLE1_CYCLES[512]['fp32']) - wm.PAPER_512_FP32_US) < 1.0


def test_headline_tflops():
    """§5.3: 18.9 TF/s FP32 and 32.7 TF/s FP16 at n=512."""
    assert abs(wm.tflops(512, wm.TABLE1_CYCLES[512]['fp32'])
               - wm.PAPER_512_TFLOPS['fp32']) < 0.1
    assert abs(wm.tflops(512, wm.TABLE1_CYCLES[512]['fp16'])
               - wm.PAPER_512_TFLOPS['fp16']) < 0.1


def test_table2_dgx_claim():
    """§5.4: wsFFT 18% faster than the fastest DGX 512^3 FP32 result."""
    ours = wm.tflops(512, wm.TABLE1_CYCLES[512]['fp32'])
    assert abs((ours / 16.0 - 1) - 0.18) < 0.01


def test_model_tracks_table1():
    """Closed-form model within 30% of every measured cycle count, always
    a lower bound (it omits dispatch/queue overheads)."""
    for row in wm.table1_report():
        assert -0.30 < row['rel_err'] < 0.0, row


def test_eq5_fp32_at_most_2x_fp16():
    """Eq. 5: TT_comm_FP32(n) <= 2 * TT_comm_FP16(n)."""
    for lg in range(5, 11):
        n = 1 << lg
        assert wm.tt_comm_single(n, 'fp32') <= 2 * wm.tt_comm_single(n, 'fp16')


def test_eq7_multipencil_bound():
    """Eq. 7: TT_comm(n, m) <= m * TT_comm(n, 1)."""
    for n in (64, 256, 1024):
        for m in (2, 4, 8):
            for prec in ('fp16', 'fp32'):
                assert wm.tt_comm(n, m, prec) <= m * wm.tt_comm(n, 1, prec) + 1e-9


def test_pencil_throughput_endpoints():
    """Fig 3 endpoints: 0.89 flops/cycle FP16 @4096, 0.57 FP32 @2048
    (model within 10% of the measured values)."""
    n, v = wm.PAPER_PENCIL_FLOPS_PER_CYCLE['fp16']
    assert abs(wm.pencil_flops_per_cycle(n, 'fp16') - v) / v < 0.10
    n, v = wm.PAPER_PENCIL_FLOPS_PER_CYCLE['fp32']
    assert abs(wm.pencil_flops_per_cycle(n, 'fp32') - v) / v < 0.10


def test_pencil_asymptotes():
    """§5.1: asymptotes 5/3 (FP16) and 5/6.5 (FP32) flops/cycle —
    the paper computes these from the n*log2(n) term ONLY."""
    assert abs(wm.pencil_asymptote('fp16')
               - wm.PAPER_PENCIL_ASYMPTOTE['fp16']) < 0.02
    assert abs(wm.pencil_asymptote('fp32')
               - wm.PAPER_PENCIL_ASYMPTOTE['fp32']) < 0.02
    # and the finite-n model monotonically approaches it from below
    prev = 0.0
    for lg in range(6, 23, 4):
        cur = wm.pencil_flops_per_cycle(1 << lg, 'fp16')
        assert prev < cur < wm.pencil_asymptote('fp16')
        prev = cur


def test_strong_scaling_speedups():
    """§5.3: 2.85x speedup scaling 256^3 FP32 from 64x64 to 128x128, and
    2.54x on the next step (reconstruction within 5%)."""
    s1 = wm.et_total_strong(256, 4, 'fp32') / wm.et_total_strong(256, 2, 'fp32')
    s2 = wm.et_total_strong(256, 2, 'fp32') / wm.TABLE1_CYCLES[256]['fp32']
    assert abs(s1 - 2.85) / 2.85 < 0.05, s1
    assert abs(s2 - 2.54) / 2.54 < 0.05, s2


def test_1024_strong_estimates():
    """Table 2 starred rows: 22.5 TF/s FP32 and 36 TF/s FP16 for 1024^3
    on a 512x512 submesh (m=2)."""
    fp16 = wm.tflops(1024, wm.et_total_1024_strong(2, 'fp16'))
    fp32 = wm.tflops(1024, wm.et_total_1024_strong(2, 'fp32'))
    assert abs(fp16 - 36.0) / 36.0 < 0.05, fp16
    assert abs(fp32 - 22.5) / 22.5 < 0.10, fp32


def test_bisection_bandwidth():
    """§6.2: 3.5 TB/s bisection bandwidth for a 512x512 mesh."""
    assert abs(wm.bisection_bw_tbs(512) - 3.5) < 0.1


def test_router_bandwidth():
    """§5.3: 0.8 PB/s total router bandwidth at n=512 FP32."""
    assert abs(wm.router_bw_pbs(512, 'fp32') - 0.8) / 0.8 < 0.10


def test_comm_dominates_at_scale():
    """§9: transposes dominate the runtime — up to ~80% at sizes of
    interest."""
    _, comm = wm.measured_split(512, 'fp32')
    share = comm / wm.TABLE1_CYCLES[512]['fp32']
    assert 0.70 < share < 0.90


def test_fp32_comm_ratio_at_512():
    """§5.3: measured FP32 communication at n=512 is ~1.8x FP16."""
    _, c32 = wm.measured_split(512, 'fp32')
    _, c16 = wm.measured_split(512, 'fp16')
    assert abs(c32 / c16 - 1.8) < 0.15


def test_flop_count_definition():
    assert wm.fft_flops_1d(512) == 5 * 512 * 9
    assert wm.fft_flops_3d(512) == 3 * 512 ** 2 * 5 * 512 * 9
